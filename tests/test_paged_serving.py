"""Paged KV cache + continuous batching: oracle equivalence, admission,
page accounting, ring prefill, and the fused-step sampling path.

The paged-vs-dense pipeline tests run in float32 so the two cache layouts
are comparable at tight tolerance (bf16 cross-path rounding would otherwise
amplify through layers); greedy token streams must match exactly either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import attention, lm
from repro.serving.scheduler import (ContinuousBatchingEngine, PageAllocator,
                                     Request, bucket_len)

B, MAX_LEN, PS = 3, 32, 8


def _f32(params):
    return jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def llm():
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    return cfg, _f32(lm.init(jax.random.PRNGKey(0), cfg))


@pytest.fixture(scope="module")
def ring_llm():
    """Mixed full-attention + ring-window local pattern."""
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    cfg = cfg.replace(block_pattern=("attn", "local"), num_layers=4,
                      window=16, ring_local_cache=True)
    return cfg, _f32(lm.init(jax.random.PRNGKey(1), cfg))


def _paged_cache(cfg, batch=B, max_len=MAX_LEN, ps=PS):
    cache = lm.init_cache(cfg, batch, max_len, dtype=jnp.float32,
                          paged=True, page_size=ps)
    return lm.set_block_tables(
        cache, attention.default_block_tables(batch, max_len, ps))


def _run_pipeline(cfg, params, cache, prompts, lengths, steps, impl="ref"):
    logits, cache = lm.prefill(params, cfg, prompts, cache, impl=impl,
                               lengths=lengths)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = lengths if lengths is not None else jnp.full(
        (prompts.shape[0],), prompts.shape[1], jnp.int32)
    out = [np.asarray(tok)]
    for _ in range(steps):
        logits, cache = lm.decode_step(params, cfg, tok, cache, pos,
                                       impl=impl)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1
        out.append(np.asarray(tok))
    return np.stack(out, 1), logits, cache


# ---------------------------------------------------------------------------
# Paged == dense oracle (prefill -> decode, ragged lengths, ring configs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_paged_matches_dense_ragged_pipeline(llm, impl):
    cfg, params = llm
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(2, 100, (B, 8)), jnp.int32)
    lengths = jnp.asarray([8, 3, 5], jnp.int32)

    dense = lm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32)
    toks_d, logits_d, _ = _run_pipeline(cfg, params, dense, prompts,
                                        lengths, steps=10)
    toks_p, logits_p, _ = _run_pipeline(cfg, params, _paged_cache(cfg),
                                        prompts, lengths, steps=10,
                                        impl=impl)
    np.testing.assert_array_equal(toks_d, toks_p)
    tol = dict(rtol=1e-4, atol=1e-4) if impl == "ref" else dict(
        rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_p),
                               **tol)


def test_paged_matches_dense_ring_window_config(ring_llm):
    """Mixed pattern: attn layers paged, local layers keep their ring cache
    (bounded by the window already) — still bit-compatible with dense."""
    cfg, params = ring_llm
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(rng.integers(2, 100, (B, 6)), jnp.int32)
    lengths = jnp.asarray([6, 2, 4], jnp.int32)

    dense = lm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32)
    toks_d, _, _ = _run_pipeline(cfg, params, dense, prompts, lengths,
                                 steps=12)
    paged = _paged_cache(cfg)
    toks_p, _, cache_p = _run_pipeline(cfg, params, paged, prompts, lengths,
                                       steps=12)
    np.testing.assert_array_equal(toks_d, toks_p)
    # The local layer's cache really is a ring (window-sized), not paged.
    local = cache_p["groups"]["1"]
    assert "k" in local and local["k"].shape[-2] == cfg.window
    assert "k_pages" in cache_p["groups"]["0"]


def test_ragged_prefill_preserves_untouched_rows(llm):
    """lengths[b] == 0 rows keep cache bit-for-bit (admission isolation)."""
    cfg, params = llm
    rng = np.random.default_rng(2)
    prompts = jnp.asarray(rng.integers(2, 100, (B, 8)), jnp.int32)

    # Dense: row 0's [G, Hkv, S, D] slice untouched by row 1's prefill.
    dense = lm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32)
    _, dense = lm.prefill(params, cfg, prompts, dense,
                          lengths=jnp.asarray([6, 0, 0], jnp.int32))
    row0 = np.asarray(dense["groups"]["0"]["k"][:, 0]).copy()
    _, dense = lm.prefill(params, cfg, prompts, dense,
                          lengths=jnp.asarray([0, 8, 0], jnp.int32))
    np.testing.assert_array_equal(row0,
                                  np.asarray(dense["groups"]["0"]["k"][:, 0]))

    # Paged: every page EXCEPT row 1's must be untouched by row 1's prefill
    # (this includes the pool's last page — a -1 "drop" that wrapped under
    # jnp scatter semantics would corrupt it).
    paged = _paged_cache(cfg)
    _, paged = lm.prefill(params, cfg, prompts, paged,
                          lengths=jnp.asarray([6, 0, 0], jnp.int32))
    bt = np.asarray(lm.get_block_tables(paged))
    pool_before = np.asarray(paged["groups"]["0"]["k_pages"]).copy()
    _, paged = lm.prefill(params, cfg, prompts, paged,
                          lengths=jnp.asarray([0, 8, 0], jnp.int32))
    pool_after = np.asarray(paged["groups"]["0"]["k_pages"])
    others = [p for p in range(pool_before.shape[1])
              if p not in set(bt[1].tolist())]
    np.testing.assert_array_equal(pool_before[:, others],
                                  pool_after[:, others])


# ---------------------------------------------------------------------------
# Ring-cache prefill gather path (prompt longer than the ring)
# ---------------------------------------------------------------------------

def test_ring_prefill_gather_matches_decode_fill():
    """attention.prefill with t > S (ring) must leave the same cache as
    feeding the tokens through decode_step one at a time."""
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32,
                          vocab=128).replace(window=4)
    key = jax.random.PRNGKey(3)
    p = jax.tree.map(lambda x: x.astype(jnp.float32),
                     attention.init(key, cfg))
    t, s = 10, 4
    x = jnp.asarray(np.random.default_rng(3).normal(size=(1, t, cfg.d_model)),
                    jnp.float32)

    ring = attention.init_cache(cfg, 1, s, dtype=jnp.float32)
    mask = jnp.ones((t, t), bool) & (jnp.arange(t)[None, :]
                                     <= jnp.arange(t)[:, None])
    _, ring = attention.prefill(p, cfg, x, ring, mask, jnp.arange(t))

    step = attention.init_cache(cfg, 1, s, dtype=jnp.float32)
    for i in range(t):
        _, step = attention.decode_step(p, cfg, x[:, i:i + 1], step,
                                        jnp.asarray([i], jnp.int32))
    np.testing.assert_allclose(np.asarray(ring["k"]), np.asarray(step["k"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ring["v"]), np.asarray(step["v"]),
                               rtol=1e-5, atol=1e-5)


def test_ragged_into_short_ring_raises():
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    p = attention.init(jax.random.PRNGKey(0), cfg)
    cache = attention.init_cache(cfg, 2, 4)          # ring shorter than t
    x = jnp.zeros((2, 8, cfg.d_model), jnp.bfloat16)
    with pytest.raises(NotImplementedError, match="ragged prefill"):
        attention.prefill(p, cfg, x, cache, None, jnp.arange(8),
                          lengths=jnp.asarray([8, 2], jnp.int32))


# ---------------------------------------------------------------------------
# Continuous batching: admission, completion, page reuse
# ---------------------------------------------------------------------------

def _mk_requests(rng, spec):
    return [Request(rid=i,
                    prompt=[int(t) for t in rng.integers(2, 100, n)],
                    max_new_tokens=m)
            for i, (n, m) in enumerate(spec)]


def test_scheduler_paged_dense_solo_agree(llm):
    cfg, params = llm
    spec = [(5, 6), (9, 4), (3, 8), (7, 5), (4, 3)]
    outs = {}
    for mode in ("paged", "dense"):
        rng = np.random.default_rng(7)
        eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                       paged=(mode == "paged"), page_size=8)
        outs[mode] = eng.run(_mk_requests(rng, spec))
        assert eng.stats["completed"] == len(spec)
    rng = np.random.default_rng(7)
    solo_reqs = _mk_requests(rng, spec)
    for r in solo_reqs:
        solo = ContinuousBatchingEngine(cfg, params, batch=1, max_len=32,
                                        paged=True, page_size=8)
        solo.run([r])
    for mode in ("paged", "dense"):
        for got, want in zip(outs[mode], solo_reqs):
            assert got.tokens == want.tokens, (mode, got.rid)


def test_mid_flight_admission_reuses_pages_without_disturbing_rows(llm):
    """A finished row's pages are reallocated to the next request while the
    other row keeps decoding — its output must be unchanged vs a run with
    no admission at all."""
    cfg, params = llm
    rng = np.random.default_rng(9)
    long_req = Request(0, [int(t) for t in rng.integers(2, 100, 6)], 12)
    short_req = Request(1, [int(t) for t in rng.integers(2, 100, 4)], 2)
    late_req = Request(2, [int(t) for t in rng.integers(2, 100, 5)], 3)

    def clone(r):
        return Request(r.rid, list(r.prompt), r.max_new_tokens)

    eng3 = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                    paged=True, page_size=8, num_pages=6)
    r3 = eng3.run([clone(long_req), clone(short_req), clone(late_req)])
    assert r3[2].admitted_step > 0, "late request must be admitted mid-flight"
    assert set(r3[2].pages) & set(r3[1].pages), \
        "freed pages were not reused by the admitted request"

    eng2 = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                    paged=True, page_size=8, num_pages=6)
    r2 = eng2.run([clone(long_req), clone(short_req)])
    assert r3[0].tokens == r2[0].tokens, \
        "mid-flight admission perturbed an in-flight row"
    assert eng3.allocator.available == 6, "page leak"


def test_page_allocator_exhaustion_and_reuse():
    alloc = PageAllocator(4)
    assert alloc.alloc(0) == [] and alloc.available == 4   # [:-0] trap
    a = alloc.alloc(3)
    assert alloc.alloc(2) is None and alloc.available == 1
    alloc.free(a)
    assert sorted(alloc.alloc(4)) == sorted(a + [3])


def test_bucket_len():
    assert bucket_len(1) == 8 and bucket_len(8) == 8 and bucket_len(9) == 16
    with pytest.raises(ValueError):
        bucket_len(10_000)


def test_scheduler_requires_fitting_requests(llm):
    cfg, params = llm
    eng = ContinuousBatchingEngine(cfg, params, batch=1, max_len=16,
                                   paged=True, page_size=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(0, [3] * 20, 8))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(1, [3, 4], 0))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(2, [], 4))


# ---------------------------------------------------------------------------
# Engine paged mode + orchestrator wiring + fused-step sampling
# ---------------------------------------------------------------------------

def test_engine_paged_generate_matches_dense(llm):
    from repro.serving.engine import Engine
    cfg, params = llm
    prompts = jnp.asarray([[5, 6, 7, 8], [9, 10, 11, 12]], jnp.int32)
    dense = Engine(cfg, params, batch=2, max_len=32)
    paged = Engine(cfg, params, batch=2, max_len=32, paged=True, page_size=8)
    np.testing.assert_array_equal(
        np.asarray(dense.generate(prompts, steps=6)),
        np.asarray(paged.generate(prompts, steps=6)))


def test_engine_paged_raises_when_full(llm):
    """Pages do not ring-wrap: running past max_len must fail loudly."""
    from repro.serving.engine import Engine
    cfg, params = llm
    eng = Engine(cfg, params, batch=1, max_len=8, paged=True, page_size=8)
    eng.prefill(jnp.asarray([[5, 6, 7, 8]], jnp.int32))
    with pytest.raises(ValueError, match="paged cache is full"):
        for _ in range(10):
            eng.step()


def test_orchestrator_paged_ragged_converges():
    from repro.agents.orchestrator import make_sim_llm, run_task
    from repro.agents.tasks import TASKS
    cfg, params = make_sim_llm()
    r = run_task(cfg, params, TASKS["tic_tac_toe"], mode="parallel",
                 n_agents=3, seed=1, kv="paged", prefill="ragged")
    assert r.converged and r.gen_tokens > 0
    assert r.kv_mode == "paged" and r.prefill_mode == "ragged"
    # Ragged prefill folds each prompt into one step: far fewer engine steps
    # than replay mode, which pays one decode step per replayed token.
    replay = run_task(cfg, params, TASKS["tic_tac_toe"], mode="parallel",
                      n_agents=3, seed=1)
    assert r.steps < replay.steps


def test_fused_serve_step_temperature_sampling(llm):
    from jax.sharding import Mesh
    from repro.core import doc as doc_mod, gset
    from repro.serving import engine as engine_mod
    cfg, params = llm
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    coord = engine_mod.replicate_coord(
        {"doc": doc_mod.empty(4, 16), "heartbeats": gset.GCounter.zeros(1)},
        1)
    step = engine_mod.make_fused_serve_step(cfg, mesh, ("data",),
                                            temperature=1.0)
    cache = lm.init_cache(cfg, 4, 16)
    token = jnp.asarray([3, 3, 3, 3], jnp.int32)
    pos = jnp.zeros((4,), jnp.int32)
    slots = jnp.arange(4, dtype=jnp.int32)
    active = jnp.ones((4,), bool)
    seen = set()
    key = jax.random.PRNGKey(0)
    with mesh:
        for t in range(5):
            key, sub = jax.random.split(key)
            token, cache, pos, coord = step(params, cache, token, pos,
                                            slots, active, coord,
                                            jnp.int32(t), sub)
            seen.update(np.asarray(token).tolist())
    assert len(seen) > 1, "temperature sampling had no effect in fused step"


# ---------------------------------------------------------------------------
# Benchmark accounting: the write really is O(page), not O(max_len)
# ---------------------------------------------------------------------------

def test_serving_write_bytes_o_page_not_o_max_len(llm):
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.bench_serving import analytic_step_bytes
    cfg, _ = llm
    live = [40, 10, 100]
    w_dense_1k, _ = analytic_step_bytes(cfg, batch=3, max_len=1024,
                                        page_size=16, live_lens=live,
                                        paged=False)
    w_dense_4k, _ = analytic_step_bytes(cfg, batch=3, max_len=4096,
                                        page_size=16, live_lens=live,
                                        paged=False)
    w_paged_1k, r_paged_1k = analytic_step_bytes(cfg, batch=3, max_len=1024,
                                                 page_size=16,
                                                 live_lens=live, paged=True)
    w_paged_4k, r_paged_4k = analytic_step_bytes(cfg, batch=3, max_len=4096,
                                                 page_size=16,
                                                 live_lens=live, paged=True)
    assert w_dense_4k == 4 * w_dense_1k          # dense write ~ max_len
    assert w_paged_4k == w_paged_1k              # paged write ~ O(page)
    assert r_paged_4k == r_paged_1k              # reads ~ live tokens
    assert w_dense_1k // w_paged_1k == 1024      # the headline ratio


# ---------------------------------------------------------------------------
# Reservation path, incremental growth, preemption, COW prefix sharing
# ---------------------------------------------------------------------------

def test_allocator_reservation_prevents_double_admission():
    """Two candidates checked against one availability snapshot must not
    both pass: reserve() removes pages from the free list immediately."""
    alloc = PageAllocator(4)
    assert alloc.available == 4
    res_a = alloc.reserve(3)
    assert res_a is not None and alloc.available == 1
    # Candidate B sees the truth: its 3-page ask fails even though A has
    # not been committed/prefilled yet (the double-admission race).
    assert alloc.reserve(3) is None
    pages_a = res_a.take()
    assert len(pages_a) == 3
    res_c = alloc.reserve(1)
    assert res_c is not None and alloc.available == 0
    res_c.release()
    assert alloc.available == 1
    alloc.free(pages_a)
    assert alloc.available == 4


def test_allocator_refcounts_share_and_free():
    alloc = PageAllocator(2)
    (p,) = alloc.alloc(1)
    gen0 = alloc.generation(p)
    alloc.share([p])
    assert alloc.refcount(p) == 2
    alloc.free([p])
    assert alloc.refcount(p) == 1 and alloc.available == 1
    alloc.free([p])
    assert alloc.available == 2
    with pytest.raises(ValueError):
        alloc.free([p])
    (p2,) = alloc.alloc(1)
    if p2 == p:
        assert alloc.generation(p) == gen0 + 1   # reuse is detectable


def test_admission_is_two_phase_under_page_pressure(llm):
    """With pages for only one of two head-of-queue requests, exactly one
    is admitted per round — never both against the same snapshot."""
    cfg, params = llm
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=[int(t) for t in rng.integers(2, 100, 14)],
                    max_new_tokens=2) for i in range(2)]
    eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                   paged=True, page_size=8, num_pages=3)
    for r in reqs:
        eng.submit(r)
    eng.admit()
    assert sum(r is not None for r in eng.rows) == 1
    assert eng.allocator.available == 1        # 2 pages reserved+taken


def test_incremental_growth_allocates_on_boundary_crossing(llm):
    """Admission allocates only the prompt's pages; generation pages appear
    as decode crosses page boundaries (no whole-request up-front alloc)."""
    cfg, params = llm
    req = Request(0, [3] * 6, 18)              # 6 + 18 = 24 slots = 3 pages
    eng = ContinuousBatchingEngine(cfg, params, batch=1, max_len=32,
                                   paged=True, page_size=8)
    eng.submit(req)
    eng.admit()
    assert len(req.pages) == 1                 # ceil(6/8): prompt only
    while eng.step():
        pass
    assert eng.stats["grown_pages"] == 2       # pages 2 and 3 on crossing
    assert len(req.tokens) == 18
    assert eng.allocator.available == eng.allocator.num_pages


def test_lru_preemption_recomputes_and_completes(llm):
    """Pool too small for both rows' full horizons: the least-recently
    allocating row is preempted (pages freed, request re-queued with its
    generated tokens) and everything still completes without leaks."""
    cfg, params = llm
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=[int(t) for t in rng.integers(2, 100, 6)],
                    max_new_tokens=12) for i in range(2)]
    # Each needs ceil((6+12)/8) = 3 pages at peak; pool of 4 forces a
    # preemption when both try to grow.
    eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                   paged=True, page_size=8, num_pages=4)
    eng.run(list(reqs))
    assert eng.stats["completed"] == 2
    assert eng.stats["preemptions"] >= 1
    assert all(len(r.tokens) == 12 for r in reqs)
    assert eng.allocator.available == 4, "page leak after preemption"


def test_prefix_sharing_cow_matches_unshared_tokens(llm):
    """Fan-out from one prompt: shared admission + COW must produce exactly
    the tokens of the non-shared run, with strictly fewer resident pages."""
    cfg, params = llm
    rng = np.random.default_rng(11)
    prompt = [int(t) for t in rng.integers(2, 100, 13)]   # 1 full + partial
    fanout = 4

    def run(share):
        reqs = [Request(rid=i, prompt=list(prompt), max_new_tokens=6)
                for i in range(fanout)]
        eng = ContinuousBatchingEngine(cfg, params, batch=fanout,
                                       max_len=32, paged=True, page_size=8,
                                       prefix_sharing=share)
        eng.run(reqs)
        assert eng.stats["completed"] == fanout
        assert eng.allocator.available == eng.allocator.num_pages
        return reqs, eng

    plain, eng_plain = run(False)
    shared, eng_shared = run(True)
    for a, b in zip(plain, shared):
        assert a.tokens == b.tokens, a.rid
    assert eng_shared.stats["shared_pages"] > 0
    assert eng_shared.stats["cow_copies"] > 0
    assert (eng_shared.stats["peak_pages"]
            < eng_plain.stats["peak_pages"]), "sharing saved no pages"


def test_prefix_share_resident_mb_below_unshared_at_fanout_4(llm):
    """Acceptance: bench prefix-share column shows shared < non-shared."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.bench_serving import run_prefix_share
    cfg, params = llm
    rows = {share: run_prefix_share(cfg, params, max_len=64, page_size=8,
                                    fanout=4, prompt_len=21, max_new=4,
                                    share=share)
            for share in (False, True)}
    assert rows[True]["resident_cache_mb"] < rows[False]["resident_cache_mb"]
    assert rows[True]["shared_pages"] > 0
    assert rows[True]["completed"] == rows[False]["completed"] == 4


def test_zero_page_admission_fully_covered_by_shared_prefix(llm):
    """A clone whose prompt pages are all shared needs ZERO fresh pages at
    admission (reserve(0)) and still decodes correctly."""
    cfg, params = llm
    rng = np.random.default_rng(13)
    prompt = [int(t) for t in rng.integers(2, 100, 16)]   # exactly 2 pages
    reqs = [Request(rid=i, prompt=list(prompt), max_new_tokens=4)
            for i in range(2)]
    eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                   paged=True, page_size=8,
                                   prefix_sharing=True)
    for r in reqs:
        eng.submit(r)
    eng.admit()
    # Clone shares both prompt pages: identical page lists, refcount 2.
    assert reqs[1].pages == reqs[0].pages
    assert all(eng.allocator.refcount(p) == 2 for p in reqs[1].pages)
    while eng.step():
        pass
    assert reqs[0].tokens == reqs[1].tokens
    assert eng.allocator.available == eng.allocator.num_pages


def test_zero_length_ragged_row_does_not_cow_shared_pages(llm):
    """Satellite: an admission prefill whose OTHER rows have length 0 must
    not touch pages still shared between live rows — no copy-on-write, no
    pool bytes moved outside the admitted row's pages."""
    cfg, params = llm
    rng = np.random.default_rng(17)
    prompt = [int(t) for t in rng.integers(2, 100, 13)]
    eng = ContinuousBatchingEngine(cfg, params, batch=3, max_len=32,
                                   paged=True, page_size=8,
                                   prefix_sharing=True)
    a = Request(0, list(prompt), 8)
    b = Request(1, list(prompt), 8)
    eng.submit(a)
    eng.submit(b)
    eng.admit()                                # rows 0,1 share prompt pages
    shared_pages = [p for p in a.pages if eng.allocator.refcount(p) > 1]
    assert shared_pages, "setup: prompt pages must be shared"
    cow_before = eng.stats["cow_copies"]
    pool_before = np.asarray(eng.cache["groups"]["0"]["k_pages"]).copy()

    # Admit a THIRD request with a different prompt into the free row: the
    # ragged prefill's other rows are zero-length, and rows 0/1's shared
    # pages must survive bit-for-bit with no COW triggered by admission.
    c = Request(2, [int(t) for t in rng.integers(2, 100, 5)], 2)
    eng.submit(c)
    eng.admit()
    assert eng.stats["cow_copies"] == cow_before
    pool_after = np.asarray(eng.cache["groups"]["0"]["k_pages"])
    np.testing.assert_array_equal(pool_before[:, shared_pages],
                                  pool_after[:, shared_pages])
    while eng.step():
        pass
    assert eng.stats["completed"] == 3
    assert eng.allocator.available == eng.allocator.num_pages


def test_freed_row_refill_under_prefix_sharing(llm):
    """A finished sharer's slot is refilled by a NEW clone while the other
    sharer still holds the prefix pages: the refill re-shares the live
    pages instead of copying them."""
    cfg, params = llm
    rng = np.random.default_rng(19)
    prompt = [int(t) for t in rng.integers(2, 100, 16)]   # 2 full pages
    long_r = Request(0, list(prompt), 12)
    short_r = Request(1, list(prompt), 2)
    late_r = Request(2, list(prompt), 3)
    eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                   paged=True, page_size=8,
                                   prefix_sharing=True)
    eng.run([long_r, short_r, late_r])
    assert eng.stats["completed"] == 3
    assert late_r.admitted_step > 0
    # The late clone re-shared the prefix pages still pinned by long_r.
    assert late_r.pages[:2] == long_r.pages[:2]
    assert eng.allocator.available == eng.allocator.num_pages


def test_prefix_page_mapper_shares_header_across_recontextualization():
    """The orchestrator's mapper: identical full-page prefixes share pages
    across rows AND across one row's own re-contextualizations."""
    from repro.serving.scheduler import PrefixPageMapper
    ps, maxp = 8, 4
    mapper = PrefixPageMapper(2, maxp, ps, trash_page=99)
    header = list(range(100, 120))              # 20 tokens: 2 full pages

    shared0 = mapper.map_row(0, header, horizon=24)
    assert shared0 == 0
    row0 = list(mapper.host_bt[0, :3])

    # A second row with the same prompt shares the 2 full header pages.
    shared1 = mapper.map_row(1, list(header), horizon=24)
    assert shared1 == 2
    assert list(mapper.host_bt[1, :2]) == row0[:2]
    assert all(mapper.allocator.refcount(p) == 2 for p in row0[:2])

    # Row 0 re-contextualizes: same header, different tail — the header
    # pages survive the remap (self-share), the tail page is fresh.
    shared0b = mapper.map_row(0, header[:16] + [7, 8, 9], horizon=24)
    assert shared0b == 2
    assert list(mapper.host_bt[0, :2]) == row0[:2]

    # A different header shares nothing.
    assert mapper.map_row(1, list(range(200, 220)), horizon=24) == 0
    mapper.free_row(0)
    mapper.free_row(1)


def test_orchestrator_paged_sharing_stat():
    """Paged orchestrator with small pages reports shared prefix pages when
    invalidations force re-contextualization (dashboard has read edges)."""
    from repro.agents.orchestrator import make_sim_llm, run_task
    from repro.agents.tasks import TASKS
    cfg, params = make_sim_llm()
    r = run_task(cfg, params, TASKS["dashboard"], mode="parallel",
                 n_agents=3, seed=0, kv="paged", prefill="ragged",
                 page_size=8)
    assert r.converged and r.kv_mode == "paged"
    if r.invalidations > 0:
        assert r.shared_prefix_pages > 0, \
            "re-contextualization shared no header pages"
