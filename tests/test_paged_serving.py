"""Paged KV cache + continuous batching: oracle equivalence, admission,
page accounting, ring prefill, and the fused-step sampling path.

The paged-vs-dense pipeline tests run in float32 so the two cache layouts
are comparable at tight tolerance (bf16 cross-path rounding would otherwise
amplify through layers); greedy token streams must match exactly either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import attention, lm
from repro.serving.scheduler import (ContinuousBatchingEngine, PageAllocator,
                                     Request, bucket_len)

B, MAX_LEN, PS = 3, 32, 8


def _f32(params):
    return jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def llm():
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    return cfg, _f32(lm.init(jax.random.PRNGKey(0), cfg))


@pytest.fixture(scope="module")
def ring_llm():
    """Mixed full-attention + ring-window local pattern."""
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    cfg = cfg.replace(block_pattern=("attn", "local"), num_layers=4,
                      window=16, ring_local_cache=True)
    return cfg, _f32(lm.init(jax.random.PRNGKey(1), cfg))


def _paged_cache(cfg, batch=B, max_len=MAX_LEN, ps=PS):
    cache = lm.init_cache(cfg, batch, max_len, dtype=jnp.float32,
                          paged=True, page_size=ps)
    return lm.set_block_tables(
        cache, attention.default_block_tables(batch, max_len, ps))


def _run_pipeline(cfg, params, cache, prompts, lengths, steps, impl="ref"):
    logits, cache = lm.prefill(params, cfg, prompts, cache, impl=impl,
                               lengths=lengths)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = lengths if lengths is not None else jnp.full(
        (prompts.shape[0],), prompts.shape[1], jnp.int32)
    out = [np.asarray(tok)]
    for _ in range(steps):
        logits, cache = lm.decode_step(params, cfg, tok, cache, pos,
                                       impl=impl)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1
        out.append(np.asarray(tok))
    return np.stack(out, 1), logits, cache


# ---------------------------------------------------------------------------
# Paged == dense oracle (prefill -> decode, ragged lengths, ring configs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_paged_matches_dense_ragged_pipeline(llm, impl):
    cfg, params = llm
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(2, 100, (B, 8)), jnp.int32)
    lengths = jnp.asarray([8, 3, 5], jnp.int32)

    dense = lm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32)
    toks_d, logits_d, _ = _run_pipeline(cfg, params, dense, prompts,
                                        lengths, steps=10)
    toks_p, logits_p, _ = _run_pipeline(cfg, params, _paged_cache(cfg),
                                        prompts, lengths, steps=10,
                                        impl=impl)
    np.testing.assert_array_equal(toks_d, toks_p)
    tol = dict(rtol=1e-4, atol=1e-4) if impl == "ref" else dict(
        rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_p),
                               **tol)


def test_paged_matches_dense_ring_window_config(ring_llm):
    """Mixed pattern: attn layers paged, local layers keep their ring cache
    (bounded by the window already) — still bit-compatible with dense."""
    cfg, params = ring_llm
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(rng.integers(2, 100, (B, 6)), jnp.int32)
    lengths = jnp.asarray([6, 2, 4], jnp.int32)

    dense = lm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32)
    toks_d, _, _ = _run_pipeline(cfg, params, dense, prompts, lengths,
                                 steps=12)
    paged = _paged_cache(cfg)
    toks_p, _, cache_p = _run_pipeline(cfg, params, paged, prompts, lengths,
                                       steps=12)
    np.testing.assert_array_equal(toks_d, toks_p)
    # The local layer's cache really is a ring (window-sized), not paged.
    local = cache_p["groups"]["1"]
    assert "k" in local and local["k"].shape[-2] == cfg.window
    assert "k_pages" in cache_p["groups"]["0"]


def test_ragged_prefill_preserves_untouched_rows(llm):
    """lengths[b] == 0 rows keep cache bit-for-bit (admission isolation)."""
    cfg, params = llm
    rng = np.random.default_rng(2)
    prompts = jnp.asarray(rng.integers(2, 100, (B, 8)), jnp.int32)

    # Dense: row 0's [G, Hkv, S, D] slice untouched by row 1's prefill.
    dense = lm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32)
    _, dense = lm.prefill(params, cfg, prompts, dense,
                          lengths=jnp.asarray([6, 0, 0], jnp.int32))
    row0 = np.asarray(dense["groups"]["0"]["k"][:, 0]).copy()
    _, dense = lm.prefill(params, cfg, prompts, dense,
                          lengths=jnp.asarray([0, 8, 0], jnp.int32))
    np.testing.assert_array_equal(row0,
                                  np.asarray(dense["groups"]["0"]["k"][:, 0]))

    # Paged: every page EXCEPT row 1's must be untouched by row 1's prefill
    # (this includes the pool's last page — a -1 "drop" that wrapped under
    # jnp scatter semantics would corrupt it).
    paged = _paged_cache(cfg)
    _, paged = lm.prefill(params, cfg, prompts, paged,
                          lengths=jnp.asarray([6, 0, 0], jnp.int32))
    bt = np.asarray(lm.get_block_tables(paged))
    pool_before = np.asarray(paged["groups"]["0"]["k_pages"]).copy()
    _, paged = lm.prefill(params, cfg, prompts, paged,
                          lengths=jnp.asarray([0, 8, 0], jnp.int32))
    pool_after = np.asarray(paged["groups"]["0"]["k_pages"])
    others = [p for p in range(pool_before.shape[1])
              if p not in set(bt[1].tolist())]
    np.testing.assert_array_equal(pool_before[:, others],
                                  pool_after[:, others])


# ---------------------------------------------------------------------------
# Ring-cache prefill gather path (prompt longer than the ring)
# ---------------------------------------------------------------------------

def test_ring_prefill_gather_matches_decode_fill():
    """attention.prefill with t > S (ring) must leave the same cache as
    feeding the tokens through decode_step one at a time."""
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32,
                          vocab=128).replace(window=4)
    key = jax.random.PRNGKey(3)
    p = jax.tree.map(lambda x: x.astype(jnp.float32),
                     attention.init(key, cfg))
    t, s = 10, 4
    x = jnp.asarray(np.random.default_rng(3).normal(size=(1, t, cfg.d_model)),
                    jnp.float32)

    ring = attention.init_cache(cfg, 1, s, dtype=jnp.float32)
    mask = jnp.ones((t, t), bool) & (jnp.arange(t)[None, :]
                                     <= jnp.arange(t)[:, None])
    _, ring = attention.prefill(p, cfg, x, ring, mask, jnp.arange(t))

    step = attention.init_cache(cfg, 1, s, dtype=jnp.float32)
    for i in range(t):
        _, step = attention.decode_step(p, cfg, x[:, i:i + 1], step,
                                        jnp.asarray([i], jnp.int32))
    np.testing.assert_allclose(np.asarray(ring["k"]), np.asarray(step["k"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ring["v"]), np.asarray(step["v"]),
                               rtol=1e-5, atol=1e-5)


def test_ragged_into_short_ring_raises():
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    p = attention.init(jax.random.PRNGKey(0), cfg)
    cache = attention.init_cache(cfg, 2, 4)          # ring shorter than t
    x = jnp.zeros((2, 8, cfg.d_model), jnp.bfloat16)
    with pytest.raises(NotImplementedError, match="ragged prefill"):
        attention.prefill(p, cfg, x, cache, None, jnp.arange(8),
                          lengths=jnp.asarray([8, 2], jnp.int32))


# ---------------------------------------------------------------------------
# Continuous batching: admission, completion, page reuse
# ---------------------------------------------------------------------------

def _mk_requests(rng, spec):
    return [Request(rid=i,
                    prompt=[int(t) for t in rng.integers(2, 100, n)],
                    max_new_tokens=m)
            for i, (n, m) in enumerate(spec)]


def test_scheduler_paged_dense_solo_agree(llm):
    cfg, params = llm
    spec = [(5, 6), (9, 4), (3, 8), (7, 5), (4, 3)]
    outs = {}
    for mode in ("paged", "dense"):
        rng = np.random.default_rng(7)
        eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                       paged=(mode == "paged"), page_size=8)
        outs[mode] = eng.run(_mk_requests(rng, spec))
        assert eng.stats["completed"] == len(spec)
    rng = np.random.default_rng(7)
    solo_reqs = _mk_requests(rng, spec)
    for r in solo_reqs:
        solo = ContinuousBatchingEngine(cfg, params, batch=1, max_len=32,
                                        paged=True, page_size=8)
        solo.run([r])
    for mode in ("paged", "dense"):
        for got, want in zip(outs[mode], solo_reqs):
            assert got.tokens == want.tokens, (mode, got.rid)


def test_mid_flight_admission_reuses_pages_without_disturbing_rows(llm):
    """A finished row's pages are reallocated to the next request while the
    other row keeps decoding — its output must be unchanged vs a run with
    no admission at all."""
    cfg, params = llm
    rng = np.random.default_rng(9)
    long_req = Request(0, [int(t) for t in rng.integers(2, 100, 6)], 12)
    short_req = Request(1, [int(t) for t in rng.integers(2, 100, 4)], 2)
    late_req = Request(2, [int(t) for t in rng.integers(2, 100, 5)], 3)

    def clone(r):
        return Request(r.rid, list(r.prompt), r.max_new_tokens)

    eng3 = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                    paged=True, page_size=8, num_pages=6)
    r3 = eng3.run([clone(long_req), clone(short_req), clone(late_req)])
    assert r3[2].admitted_step > 0, "late request must be admitted mid-flight"
    assert set(r3[2].pages) & set(r3[1].pages), \
        "freed pages were not reused by the admitted request"

    eng2 = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                    paged=True, page_size=8, num_pages=6)
    r2 = eng2.run([clone(long_req), clone(short_req)])
    assert r3[0].tokens == r2[0].tokens, \
        "mid-flight admission perturbed an in-flight row"
    assert eng3.allocator.available == 6, "page leak"


def test_page_allocator_exhaustion_and_reuse():
    alloc = PageAllocator(4)
    assert alloc.alloc(0) == [] and alloc.available == 4   # [:-0] trap
    a = alloc.alloc(3)
    assert alloc.alloc(2) is None and alloc.available == 1
    alloc.free(a)
    assert sorted(alloc.alloc(4)) == sorted(a + [3])


def test_bucket_len():
    assert bucket_len(1) == 8 and bucket_len(8) == 8 and bucket_len(9) == 16
    with pytest.raises(ValueError):
        bucket_len(10_000)


def test_scheduler_requires_fitting_requests(llm):
    cfg, params = llm
    eng = ContinuousBatchingEngine(cfg, params, batch=1, max_len=16,
                                   paged=True, page_size=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(0, [3] * 20, 8))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(1, [3, 4], 0))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(2, [], 4))


# ---------------------------------------------------------------------------
# Engine paged mode + orchestrator wiring + fused-step sampling
# ---------------------------------------------------------------------------

def test_engine_paged_generate_matches_dense(llm):
    from repro.serving.engine import Engine
    cfg, params = llm
    prompts = jnp.asarray([[5, 6, 7, 8], [9, 10, 11, 12]], jnp.int32)
    dense = Engine(cfg, params, batch=2, max_len=32)
    paged = Engine(cfg, params, batch=2, max_len=32, paged=True, page_size=8)
    np.testing.assert_array_equal(
        np.asarray(dense.generate(prompts, steps=6)),
        np.asarray(paged.generate(prompts, steps=6)))


def test_engine_paged_raises_when_full(llm):
    """Pages do not ring-wrap: running past max_len must fail loudly."""
    from repro.serving.engine import Engine
    cfg, params = llm
    eng = Engine(cfg, params, batch=1, max_len=8, paged=True, page_size=8)
    eng.prefill(jnp.asarray([[5, 6, 7, 8]], jnp.int32))
    with pytest.raises(ValueError, match="paged cache is full"):
        for _ in range(10):
            eng.step()


def test_orchestrator_paged_ragged_converges():
    from repro.agents.orchestrator import make_sim_llm, run_task
    from repro.agents.tasks import TASKS
    cfg, params = make_sim_llm()
    r = run_task(cfg, params, TASKS["tic_tac_toe"], mode="parallel",
                 n_agents=3, seed=1, kv="paged", prefill="ragged")
    assert r.converged and r.gen_tokens > 0
    assert r.kv_mode == "paged" and r.prefill_mode == "ragged"
    # Ragged prefill folds each prompt into one step: far fewer engine steps
    # than replay mode, which pays one decode step per replayed token.
    replay = run_task(cfg, params, TASKS["tic_tac_toe"], mode="parallel",
                      n_agents=3, seed=1)
    assert r.steps < replay.steps


def test_fused_serve_step_temperature_sampling(llm):
    from jax.sharding import Mesh
    from repro.core import doc as doc_mod, gset
    from repro.serving import engine as engine_mod
    cfg, params = llm
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    coord = engine_mod.replicate_coord(
        {"doc": doc_mod.empty(4, 16), "heartbeats": gset.GCounter.zeros(1)},
        1)
    step = engine_mod.make_fused_serve_step(cfg, mesh, ("data",),
                                            temperature=1.0)
    cache = lm.init_cache(cfg, 4, 16)
    token = jnp.asarray([3, 3, 3, 3], jnp.int32)
    pos = jnp.zeros((4,), jnp.int32)
    slots = jnp.arange(4, dtype=jnp.int32)
    active = jnp.ones((4,), bool)
    seen = set()
    key = jax.random.PRNGKey(0)
    with mesh:
        for t in range(5):
            key, sub = jax.random.split(key)
            token, cache, pos, coord = step(params, cache, token, pos,
                                            slots, active, coord,
                                            jnp.int32(t), sub)
            seen.update(np.asarray(token).tolist())
    assert len(seen) > 1, "temperature sampling had no effect in fused step"


# ---------------------------------------------------------------------------
# Benchmark accounting: the write really is O(page), not O(max_len)
# ---------------------------------------------------------------------------

def test_serving_write_bytes_o_page_not_o_max_len(llm):
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.bench_serving import analytic_step_bytes
    cfg, _ = llm
    live = [40, 10, 100]
    w_dense_1k, _ = analytic_step_bytes(cfg, batch=3, max_len=1024,
                                        page_size=16, live_lens=live,
                                        paged=False)
    w_dense_4k, _ = analytic_step_bytes(cfg, batch=3, max_len=4096,
                                        page_size=16, live_lens=live,
                                        paged=False)
    w_paged_1k, r_paged_1k = analytic_step_bytes(cfg, batch=3, max_len=1024,
                                                 page_size=16,
                                                 live_lens=live, paged=True)
    w_paged_4k, r_paged_4k = analytic_step_bytes(cfg, batch=3, max_len=4096,
                                                 page_size=16,
                                                 live_lens=live, paged=True)
    assert w_dense_4k == 4 * w_dense_1k          # dense write ~ max_len
    assert w_paged_4k == w_paged_1k              # paged write ~ O(page)
    assert r_paged_4k == r_paged_1k              # reads ~ live tokens
    assert w_dense_1k // w_paged_1k == 1024      # the headline ratio
