"""Per-architecture smoke tests on REDUCED configs (full configs are only
exercised by the dry-run, which never allocates).

For every assigned arch: instantiate the reduced family-preserving config,
run one training forward + loss + grad step, assert output shapes and no
NaNs; then run prefill + a few decode steps and check they agree with the
full-sequence forward (the serving-path parity check).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm

ARCH_IDS = sorted(configs.ARCHS)


def _inputs(cfg, batch=2, t=16, seed=0):
    rng = np.random.default_rng(seed)
    out = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, t)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, t)),
                               jnp.int32),
        "loss_mask": jnp.ones((batch, t), jnp.float32),
    }
    if cfg.num_prefix_tokens:
        out["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.num_prefix_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg.is_encdec:
        out["enc_frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder.seq_len, cfg.d_model)),
            jnp.bfloat16)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = configs.reduced(configs.get(arch))
    params = lm.init(jax.random.PRNGKey(0), cfg)
    batch = _inputs(cfg)
    logits, aux = jax.jit(
        lambda p, b: lm.forward(p, cfg, b["tokens"],
                                prefix_embeds=b.get("prefix_embeds"),
                                enc_frames=b.get("enc_frames")))(params, batch)
    t_total = batch["tokens"].shape[1] + cfg.num_prefix_tokens
    assert logits.shape == (2, t_total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss, metrics = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
    # Reduced vocab: initial loss should be near ln(V).
    assert float(metrics["xent"]) < np.log(cfg.vocab_size) + 2.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_grad_step_no_nans(arch):
    cfg = configs.reduced(configs.get(arch))
    params = lm.init(jax.random.PRNGKey(1), cfg)
    batch = _inputs(cfg, batch=2, t=8)

    @jax.jit
    def grads(p, b):
        return jax.grad(lambda q: lm.loss_fn(q, cfg, b)[0])(p)

    g = grads(params, batch)
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in flat)
    # At least some gradient signal everywhere important.
    gnorm = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32)))) for x in flat)
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """Serving parity: prefill(P) + decode steps == full forward logits."""
    cfg = configs.reduced(configs.get(arch))
    params = lm.init(jax.random.PRNGKey(2), cfg)
    b, p_len, extra = 2, 8, 3
    batch = _inputs(cfg, batch=b, t=p_len + extra, seed=3)
    tokens = batch["tokens"]

    full_logits, _ = lm.forward(params, cfg, tokens,
                                prefix_embeds=batch.get("prefix_embeds"),
                                enc_frames=batch.get("enc_frames"))

    max_len = p_len + extra + cfg.num_prefix_tokens + 2
    cache = lm.init_cache(cfg, b, max_len)
    logits_p, cache = lm.prefill(params, cfg, tokens[:, :p_len], cache,
                                 prefix_embeds=batch.get("prefix_embeds"),
                                 enc_frames=batch.get("enc_frames"))
    outs = [logits_p]
    pos = jnp.full((b,), p_len + cfg.num_prefix_tokens, jnp.int32)
    for i in range(extra):
        logits_d, cache = lm.decode_step(params, cfg, tokens[:, p_len + i],
                                         cache, pos)
        outs.append(logits_d)
        pos = pos + 1

    got = np.stack([np.asarray(o, np.float32) for o in outs], axis=1)
    want = np.asarray(full_logits, np.float32)[
        :, cfg.num_prefix_tokens + p_len - 1:
        cfg.num_prefix_tokens + p_len + extra]
    np.testing.assert_allclose(got, want, rtol=0.1, atol=0.15)
    # Argmax agreement is the serving-relevant check.
    assert (got.argmax(-1) == want.argmax(-1)).mean() > 0.95


def test_param_counts_match_assignment_scale():
    """Full configs should land near their nameplate sizes."""
    expect = {
        "granite-34b": (30e9, 40e9),
        "olmo-1b": (0.9e9, 1.6e9),
        "command-r-plus-104b": (90e9, 115e9),
        "starcoder2-15b": (13e9, 18e9),
        "xlstm-125m": (0.10e9, 0.20e9),
        "whisper-tiny": (0.02e9, 0.08e9),
        "recurrentgemma-2b": (2.0e9, 3.5e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "deepseek-v2-lite-16b": (14e9, 20e9),
        "paligemma-3b": (2.0e9, 3.5e9),     # decoder only (vision stubbed)
    }
    for name, (lo, hi) in expect.items():
        n = configs.get(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_active_params_moe():
    cfg = configs.get("deepseek-moe-16b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()


def test_ring_cache_parity_recurrentgemma():
    """Ring (window-bounded) local-attention cache == unbounded cache.

    Window 4, prompt 6 (> window, exercising the prefill ring-gather), then
    6 decode steps (exercising wraparound) — outputs must match the
    unbounded-cache run exactly.
    """
    cfg_full = configs.reduced(configs.get("recurrentgemma-2b")).replace(
        window=4)
    cfg_ring = cfg_full.replace(ring_local_cache=True)
    params = lm.init(jax.random.PRNGKey(5), cfg_full)
    rng = np.random.default_rng(6)
    p_len, extra = 6, 6
    tokens = jnp.asarray(rng.integers(0, cfg_full.vocab_size,
                                      (1, p_len + extra)), jnp.int32)
    outs = {}
    for name, cfg in (("full", cfg_full), ("ring", cfg_ring)):
        cache = lm.init_cache(cfg, 1, p_len + extra + 2)
        logits, cache = lm.prefill(params, cfg, tokens[:, :p_len], cache)
        seq = [np.asarray(logits, np.float32)]
        pos = jnp.full((1,), p_len, jnp.int32)
        for i in range(extra):
            logits, cache = lm.decode_step(params, cfg,
                                           tokens[:, p_len + i], cache, pos)
            seq.append(np.asarray(logits, np.float32))
            pos = pos + 1
        outs[name] = np.stack(seq)
    np.testing.assert_allclose(outs["ring"], outs["full"],
                               rtol=2e-2, atol=2e-2)
    assert (outs["ring"].argmax(-1) == outs["full"].argmax(-1)).mean() > 0.9
