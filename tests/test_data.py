"""Data pipeline tests: determinism, packing invariants, prefetch."""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis optional; see conftest")
from hypothesis import given, strategies as st

from repro.data.pipeline import DataConfig, Prefetcher, shard_batches, \
    shard_iterator


def _cfg(**kw):
    base = dict(vocab_size=101, seq_len=32, batch_size=4,
                shard_size_batches=3)
    base.update(kw)
    return DataConfig(**base)


@given(st.integers(0, 10_000), st.integers(0, 50))
def test_shard_pure_function(seed, shard):
    cfg = _cfg(seed=seed)
    a = shard_batches(cfg, shard)
    b = shard_batches(cfg, shard)
    for x, y in zip(a, b):
        for k in x:
            np.testing.assert_array_equal(x[k], y[k])


def test_different_shards_different_data():
    cfg = _cfg()
    a = shard_batches(cfg, 0)[0]["tokens"]
    b = shard_batches(cfg, 1)[0]["tokens"]
    assert not np.array_equal(a, b)


def test_packing_invariants():
    cfg = _cfg()
    for batch in shard_batches(cfg, 3):
        assert batch["tokens"].shape == (4, 32)
        assert batch["targets"].shape == (4, 32)
        assert (batch["tokens"] >= 0).all()
        assert (batch["tokens"] < cfg.vocab_size).all()
        # Targets are next-token shifted: targets[t] == full[t+1].
        assert batch["loss_mask"].max() <= 1.0
        # Every row starts with a BOS document marker.
        assert (batch["tokens"][:, 0] == 1).all()


def test_prefetcher_preserves_order_and_count():
    cfg = _cfg()
    direct = list(shard_iterator(cfg, iter(range(3))))
    fetched = list(Prefetcher(shard_iterator(cfg, iter(range(3)))))
    assert len(direct) == len(fetched) == 9
    for x, y in zip(direct, fetched):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
