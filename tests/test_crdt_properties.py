"""Property-based tests: every repro.core CRDT is a join-semilattice.

Strong eventual consistency (Shapiro et al. 2011) needs the merge to be
commutative, associative, and idempotent, and the document to be a pure
function of the op set.  These are exactly the properties hypothesis checks
here, over randomly generated replica states and delivery orders.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis optional; see conftest")
from hypothesis import given, strategies as st

from repro.core import doc, gset, lww, merge, rga

K = 8          # registers per bank
C = 4          # clients
L = 12         # log capacity


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------

def lww_banks():
    """A random LWWBank: some registers written with random (clock, client).

    Reachable-state invariants encoded in the generator: unwritten registers
    (clock == 0) hold default payloads, and the payload is a pure function of
    the op identity (a well-behaved client never reuses a clock, so two
    replicas holding the same (clock, client) hold the same value).
    """
    entry = st.tuples(
        st.integers(0, 50),        # clock (0 = unset)
        st.integers(1, C),         # client
    )
    return st.lists(entry, min_size=K, max_size=K).map(_mk_bank)


def _mk_bank(entries):
    clocks, clients = zip(*entries)
    clocks = np.asarray(clocks, np.int32)
    clients = np.where(clocks > 0, np.asarray(clients, np.int32), 0)
    values = np.where(clocks > 0, (clocks * 7 + clients * 13) % 11 - 5, 0)
    return lww.LWWBank(
        clock=jnp.asarray(clocks),
        client=jnp.asarray(clients),
        payload={"v": jnp.asarray(values.astype(np.int32))},
    )


def gcounters():
    return st.lists(st.integers(0, 20), min_size=C, max_size=C).map(
        lambda xs: gset.GCounter(jnp.asarray(np.asarray(xs, np.int32))))


def glogs():
    """Random per-client logs drawn from one shared 'ground truth' history.

    Append-only correctness: all replicas agree on row contents; they differ
    only in how much of each row they have observed.  The shared history is a
    deterministic function of nothing (fixed seed) so every generated replica
    is a valid partial view of the same execution.
    """
    return st.lists(st.integers(0, L), min_size=C, max_size=C).map(_mk_glog)


_GROUND_TRUTH = np.random.default_rng(1234).integers(0, 99, size=(C, L)).astype(np.int32)


def _mk_glog(counts):
    counts = np.asarray(counts, np.int32)
    mask = np.arange(L)[None, :] < counts[:, None]
    data = np.where(mask, _GROUND_TRUTH, 0)
    return gset.GLog(count=jnp.asarray(counts),
                     fields={"x": jnp.asarray(data)})


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# Semilattice laws
# ---------------------------------------------------------------------------

@given(lww_banks(), lww_banks())
def test_lww_merge_commutative(a, b):
    assert _trees_equal(lww.merge(a, b), lww.merge(b, a))


@given(lww_banks(), lww_banks(), lww_banks())
def test_lww_merge_associative(a, b, c):
    assert _trees_equal(lww.merge(lww.merge(a, b), c),
                        lww.merge(a, lww.merge(b, c)))


@given(lww_banks())
def test_lww_merge_idempotent(a):
    assert _trees_equal(lww.merge(a, a), a)


@given(gcounters(), gcounters(), gcounters())
def test_gcounter_laws(a, b, c):
    assert _trees_equal(a.join(b), b.join(a))
    assert _trees_equal(a.join(b).join(c), a.join(b.join(c)))
    assert _trees_equal(a.join(a), a)


@given(glogs(), glogs(), glogs())
def test_glog_laws(a, b, c):
    assert _trees_equal(a.join(b), b.join(a))
    assert _trees_equal(a.join(b).join(c), a.join(b.join(c)))
    assert _trees_equal(a.join(a), a)


@given(glogs(), glogs())
def test_glog_join_preserves_ground_truth(a, b):
    j = a.join(b)
    counts = np.asarray(j.count)
    data = np.asarray(j.fields["x"])
    for c in range(C):
        np.testing.assert_array_equal(
            data[c, :counts[c]], _GROUND_TRUTH[c, :counts[c]])


# ---------------------------------------------------------------------------
# RGA: convergence is independent of delivery/merge order
# ---------------------------------------------------------------------------

def _random_session(seed: int, n_rounds: int) -> list[rga.RGA]:
    """Simulate C clients editing concurrently with random periodic merges.

    Returns the per-client replica states (possibly divergent) at the end.
    """
    rs = np.random.default_rng(seed)
    replicas = [rga.empty(C + 1, L) for _ in range(C)]
    clocks = [1] * C
    for _ in range(n_rounds):
        who = int(rs.integers(0, C))
        client = who + 1
        state = replicas[who]
        toks, oids, n = rga.materialize_jit(state)
        n = int(n)
        if n == 0 or rs.random() < 0.5:
            origin = state.head_oid
        else:
            origin = int(oids[int(rs.integers(0, n))])
        run_len = int(rs.integers(1, 4))
        buf = np.zeros((4,), np.int32)
        buf[:run_len] = rs.integers(1, 100, size=run_len)
        clk = clocks[who]
        replicas[who] = rga.insert_run(
            state, client, clk, origin, jnp.asarray(buf), run_len)
        clocks[who] = clk + run_len
        if rs.random() < 0.3:   # random pairwise gossip
            a, b = rs.integers(0, C, size=2)
            m = rga.merge(replicas[int(a)], replicas[int(b)])
            replicas[int(a)] = replicas[int(b)] = m
            mx = int(m.max_clock())
            clocks[int(a)] = max(clocks[int(a)], mx + 1)
            clocks[int(b)] = max(clocks[int(b)], mx + 1)
    return replicas


@given(st.integers(0, 10_000), st.permutations(list(range(C))))
def test_rga_convergence_any_merge_order(seed, perm):
    replicas = _random_session(seed, 10)
    # Merge all replicas in two different orders.
    ordered = [replicas[i] for i in perm]
    m1 = merge.fold_join(ordered)
    m2 = merge.fold_join(list(reversed(ordered)))
    t1, _, n1 = rga.materialize_jit(m1)
    t2, _, n2 = rga.materialize_jit(m2)
    assert int(n1) == int(n2)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


@given(st.integers(0, 10_000))
def test_rga_materialize_pure_function_of_opset(seed):
    replicas = _random_session(seed, 8)
    full = merge.fold_join(replicas)
    # Joining any replica back in changes nothing (idempotence at scale).
    again = merge.fold_join([full] + replicas)
    t1, _, n1 = rga.materialize_jit(full)
    t2, _, n2 = rga.materialize_jit(again)
    assert int(n1) == int(n2)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


@given(st.integers(0, 10_000))
def test_rga_all_tokens_present_none_duplicated(seed):
    """Zero data loss (RQ3): the converged doc contains every inserted token
    exactly once."""
    replicas = _random_session(seed, 10)
    full = merge.fold_join(replicas)
    toks, oids, n = rga.materialize_jit(full)
    n = int(n)
    assert n == int(jnp.sum(full.count))
    oids = np.asarray(oids[:n])
    assert len(set(oids.tolist())) == n     # each op appears exactly once


# ---------------------------------------------------------------------------
# SlotDoc
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(0, 3), st.lists(st.integers(1, 9),
                                                      min_size=1, max_size=4)),
                min_size=0, max_size=10))
def test_slotdoc_partial_views_converge(edits):
    """Replicas observing different prefixes of each slot's history converge."""
    d = doc.empty(4, 16)
    history = [d]
    for slot, toks in edits:
        buf = np.zeros((4,), np.int32)
        buf[:len(toks)] = toks
        d = doc.append(d, slot, jnp.asarray(buf), len(toks))
        history.append(d)
    # Any two snapshots of the same execution must join to the later one.
    for i in range(0, len(history), 2):
        j = doc.merge(history[i], history[-1])
        assert _trees_equal(j, history[-1])
        j2 = doc.merge(history[-1], history[i])
        assert int(doc.digest(j2)) == int(doc.digest(history[-1]))


# ---------------------------------------------------------------------------
# Observation machinery
# ---------------------------------------------------------------------------

def test_observe_deltas_and_invalidation():
    from repro.core import observe
    import jax.numpy as jnp
    d = doc.empty(4, 8)
    snap = observe.snapshot(d)
    d = doc.append(d, 2, jnp.asarray([7, 8, 0, 0]), 2)
    changed = np.asarray(observe.changed_mask(snap, d))
    assert changed.tolist() == [False, False, True, False]
    deps = jnp.asarray([False, False, True, False])
    assert bool(observe.invalidations(snap, d, deps))
    assert int(observe.observation_count(snap, d)) == 2
    # Non-dep change does not invalidate.
    assert not bool(observe.invalidations(snap, d,
                                          jnp.asarray([True, False, False,
                                                       False])))


def test_rga_frontier_delta():
    from repro.core import observe
    import jax.numpy as jnp
    s = rga.empty(3, 8)
    f0 = observe.rga_frontier(s)
    s = rga.insert_run(s, 1, 5, s.head_oid, jnp.asarray([1, 2, 3, 0]), 3)
    mask = np.asarray(observe.rga_delta_mask(s, f0))
    assert mask.sum() == 3 and mask[1, :3].all()


def test_version_vector_laws():
    from repro.core.clock import VersionVector
    import jax.numpy as jnp
    a = VersionVector.zeros(4).advance(jnp.int32(1), jnp.int32(5))
    b = VersionVector.zeros(4).advance(jnp.int32(2), jnp.int32(3))
    j = a.join(b)
    assert bool(j.dominates(a)) and bool(j.dominates(b))
    assert not bool(a.dominates(b))
    assert np.asarray(j.counts).tolist() == [0, 5, 3, 0]
