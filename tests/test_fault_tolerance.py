"""Fault-tolerant serving runtime: deadlines, retry backoff, load shedding,
and crash failover (the robustness tentpole).

Engine-level tests drive ``ContinuousBatchingEngine`` directly: TTFT and
end-to-end deadlines expire queued and running requests (releasing their
pages), bounded queues shed by priority, a halted replica sheds its queue
on capacity loss, and retry backoff delays re-admission without blocking
the requests behind it.

System-level tests run the chaos harness (serving/chaos.py): a real
multi-engine server over a seeded ``FaultyChannel``, one engine crashed
mid-flight, asserting exactly-once completion, bitwise convergence, and
per-lane refcount conservation — the acceptance gate (>= 3 seeds x >= 2
fault schedules).

Agent-level tests cover the orchestrator's map-failure backoff: a transient
page-pool exhaustion idles one agent lane and retries with deterministic
jitter instead of aborting the trial.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm
from repro.serving import chaos
from repro.serving.engine import backoff_steps
from repro.serving.scheduler import (ContinuousBatchingEngine, PageAllocator,
                                     Request, COMPLETED, EXPIRED, SHED)

B, MAX_LEN, PS = 3, 32, 8


@pytest.fixture(scope="module")
def llm():
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          lm.init(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _engine(llm, **kw):
    cfg, params = llm
    kw.setdefault("batch", B)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("page_size", PS)
    kw.setdefault("chunk_size", 8)
    return ContinuousBatchingEngine(cfg, params, **kw)


def _req(rid, plen=8, new=4, **kw):
    rng = np.random.default_rng(rid)
    return Request(rid=rid, prompt=[int(t) for t in rng.integers(2, 100, plen)],
                   max_new_tokens=new, **kw)


def _drain(engine, max_steps=500):
    for _ in range(max_steps):
        if not engine.step():
            return
    raise AssertionError("engine did not drain")


# ---------------------------------------------------------------------------
# Retry backoff (engine.backoff_steps)
# ---------------------------------------------------------------------------

def test_backoff_deterministic_and_capped():
    for rid in range(5):
        for attempt in range(1, 8):
            d1 = backoff_steps(rid, attempt)
            assert d1 == backoff_steps(rid, attempt), "must be pure"
            assert 1 <= d1 < 64 + 32    # cap + max jitter (cap // 2)
    # Exponential growth dominates jitter at low attempts.
    assert backoff_steps(7, 4) > backoff_steps(7, 1)
    # Distinct rids jitter apart somewhere (no thundering herd).
    assert len({backoff_steps(r, 3) for r in range(16)}) > 1


# ---------------------------------------------------------------------------
# Deadlines: TTFT + end-to-end, queued + running
# ---------------------------------------------------------------------------

def test_ttft_deadline_expires_queued_request(llm):
    eng = _engine(llm, batch=1)
    blocker = _req(0, new=16)
    eng.submit(blocker)
    eng.step()                       # blocker binds the only row
    waiter = _req(1, ttft_deadline=2)
    eng.submit(waiter)
    for _ in range(6):
        eng.step()
    assert waiter.status == EXPIRED
    assert eng.stats["expired"] == 1
    assert eng.stats["expired_queued"] == 1
    assert blocker.status != EXPIRED


def test_ttft_deadline_expires_running_request(llm):
    # Prompt of 12 at chunk 4 needs 3 chunks to first token; TTFT 2 can
    # never be met, so the bound request expires mid-prefill.
    eng = _engine(llm, chunk_size=4)
    req = _req(0, plen=12, ttft_deadline=2)
    eng.submit(req)
    for _ in range(6):
        eng.step()
    assert req.status == EXPIRED
    assert eng.stats["expired_ttft"] == 1


def test_e2e_deadline_expires_and_releases_pages(llm):
    eng = _engine(llm, batch=1)
    free0 = eng.allocator.available
    req = _req(0, plen=8, new=20, deadline=4)
    eng.submit(req)
    for _ in range(10):
        eng.step()
    assert req.status == EXPIRED
    assert eng.stats["expired_deadline"] == 1
    assert len(req.tokens) < 20, "deadline must cut generation short"
    assert eng.allocator.available == free0, "expired request leaked pages"
    # The engine stays serviceable after the expiry.
    ok = _req(1, new=2)
    eng.submit(ok)
    _drain(eng)
    assert ok.status == COMPLETED


# ---------------------------------------------------------------------------
# Load shedding: bounded queue + capacity loss
# ---------------------------------------------------------------------------

def test_queue_full_sheds_lowest_priority(llm):
    eng = _engine(llm, batch=1, max_queue=2)
    eng.submit(_req(0, new=16))
    eng.step()                       # occupy the row; queue now empty
    lo, mid = _req(1, priority=0), _req(2, priority=1)
    eng.submit(lo)
    eng.submit(mid)                  # queue full at 2
    hi = _req(3, priority=2)
    eng.submit(hi)                   # evicts lo (lowest priority)
    assert lo.status == SHED
    assert [q.rid for q in eng.queue] == [2, 3]
    late_lo = _req(4, priority=0)
    eng.submit(late_lo)              # no victim outranked: newcomer shed
    assert late_lo.status == SHED
    assert eng.stats["shed"] == 2
    assert eng.stats["shed_queue_full"] == 2
    assert eng.stats["shed_capacity"] == 0


def test_capacity_loss_sheds_queue(llm):
    eng = _engine(llm, batch=1)
    running = _req(0, new=16)
    eng.submit(running)
    eng.step()
    stranded = [_req(1, priority=1), _req(2, priority=0)]
    for q in stranded:
        eng.submit(q)
    eng.allocator.halted = True      # majority retired this replica
    eng.step()
    assert all(q.status == SHED for q in stranded)
    assert not eng.queue
    assert eng.stats["shed_capacity"] == 2


# ---------------------------------------------------------------------------
# Retry backoff ordering at admission
# ---------------------------------------------------------------------------

def test_backoff_delays_readmission_without_blocking(llm):
    eng = _engine(llm, batch=1)
    retrying = _req(0, new=2)
    eng.submit(retrying)
    retrying.retries, retrying.retry_at = 1, 8   # backing off until step 8
    fresh = _req(1, new=2)
    eng.submit(fresh)                # behind `retrying` in FIFO order
    eng.step()
    assert eng.rows[0] is fresh, "backoff must not head-of-line block"
    _drain(eng)
    assert fresh.status == COMPLETED
    assert retrying.status == COMPLETED
    assert retrying.admitted_step >= 8, "re-admitted before backoff expired"
    assert eng.stats["retried"] == 1


# ---------------------------------------------------------------------------
# Allocator diagnostics (satellite: errors name page id and row)
# ---------------------------------------------------------------------------

def test_allocator_errors_name_page_and_row():
    alloc = PageAllocator(4)
    pages = alloc.alloc(2)
    alloc.free(pages)
    with pytest.raises(ValueError, match=r"double free of page \d+.*row 7"):
        alloc.free([pages[0]], row=7)
    with pytest.raises(ValueError, match=r"unallocated page \d+.*row 3"):
        alloc.share([pages[1]], row=3)


# ---------------------------------------------------------------------------
# Chaos harness: crash failover over faulty gossip (the acceptance gate)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chaos_llm():
    return chaos.tiny_model()


@pytest.mark.parametrize("schedule", ["lossy", "reorder_delay"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_crash_failover_exactly_once(chaos_llm, schedule, seed):
    cfg, params = chaos_llm
    trace = chaos.run_chaos(cfg, params, schedule=schedule, seed=seed)
    inv = trace["invariants"]
    assert inv["exactly_once"], trace["exactly_once_detail"]
    assert inv["converged"] and inv["drained"]
    assert inv["lane_conservation"] and inv["no_double_free"]
    assert trace["ok"]
    srv = trace["server"]
    assert srv["crashes"] == 1
    assert srv["recovered_requests"] >= 1, "crash must orphan something"
    assert srv["lost_requests"] == 0
    assert srv["dup_done_suppressed"] == 0 or inv["exactly_once"]


def test_chaos_no_crash_is_clean(chaos_llm):
    cfg, params = chaos_llm
    trace = chaos.run_chaos(cfg, params, schedule="lossy", seed=5,
                            crash_replica=None)
    assert trace["ok"]
    assert trace["server"]["recovered_requests"] == 0
    assert trace["server"]["failed_requests"] == 0


# ---------------------------------------------------------------------------
# Orchestrator: transient page-map failure backs off instead of aborting
# ---------------------------------------------------------------------------

def test_agent_map_failure_retries_with_backoff(monkeypatch):
    from repro.agents.orchestrator import make_sim_llm, run_task
    from repro.agents.tasks import TASKS
    from repro.serving import scheduler as sched

    cfg, params = make_sim_llm()
    orig = sched.PrefixPageMapper.map_row
    calls = {"n": 0}

    def flaky(self, row, tokens, horizon):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise RuntimeError("agent page pool exhausted")
        return orig(self, row, tokens, horizon)

    monkeypatch.setattr(sched.PrefixPageMapper, "map_row", flaky)
    r = run_task(cfg, params, TASKS["pomodoro"], mode="parallel",
                 n_agents=3, seed=1, kv="paged", prefill="chunked")
    assert r.agent_failures == 2
    # Both failures may land on the same agent (claim + first retry), in
    # which case one successful re-map recovers the burst.
    assert r.agent_retries >= 1, "failed maps must eventually recover"
    assert r.converged and r.gen_tokens > 0


def test_agent_map_failure_cap_propagates(monkeypatch):
    from repro.agents.orchestrator import make_sim_llm, run_task
    from repro.agents.tasks import TASKS
    from repro.serving import scheduler as sched

    cfg, params = make_sim_llm()

    def dead(self, row, tokens, horizon):
        raise RuntimeError("agent page pool exhausted")

    monkeypatch.setattr(sched.PrefixPageMapper, "map_row", dead)
    with pytest.raises(RuntimeError, match="pool exhausted"):
        run_task(cfg, params, TASKS["pomodoro"], mode="parallel",
                 n_agents=3, seed=1, kv="paged", prefill="chunked")
