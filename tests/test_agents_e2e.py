"""End-to-end multi-agent generation tests (the paper's system behaviour).

Covers: full task completion in both modes, convergence across replicas
(RQ3), claim safety under real concurrency, invalidation accounting on
coupled tasks, and the coupling-dependent raw/normalized time structure.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.agents.orchestrator import make_sim_llm, run_task
from repro.agents.tasks import TASKS


@pytest.fixture(scope="module")
def llm():
    return make_sim_llm()


@pytest.mark.parametrize("task", ["tic_tac_toe", "pomodoro"])
@pytest.mark.parametrize("mode", ["sequential", "parallel"])
def test_task_completes_and_converges(llm, task, mode):
    cfg, params = llm
    r = run_task(cfg, params, TASKS[task], mode=mode, n_agents=3, seed=1)
    assert r.converged, "replica digests diverged (SEC violated)"
    assert r.gen_tokens > 0
    assert r.steps < 20_000, "hit safety valve"
    # Every TODO produced content: volume >= todos * floor.
    assert r.gen_tokens >= TASKS[task].n_todos


def test_delta_merge_matches_full_state_sync(llm):
    """run_task(merge="delta") reproduces the full-state trajectory exactly
    (hash() is process-stable, so same-process runs are comparable) while
    shipping fewer wire bytes."""
    cfg, params = llm
    full = run_task(cfg, params, TASKS["tic_tac_toe"], mode="parallel",
                    n_agents=3, seed=6, merge="allgather")
    dlt = run_task(cfg, params, TASKS["tic_tac_toe"], mode="parallel",
                   n_agents=3, seed=6, merge="delta")
    assert dlt.converged
    assert dlt.digest == full.digest, "delta sync diverged from fold join"
    assert dlt.gen_tokens == full.gen_tokens
    assert 0 < dlt.sync_bytes < full.sync_bytes
    # Delta mode ends with >= 1 extra drain round (frontier fixed-point
    # check); with ample capacity it finds nothing to ship.
    assert full.sync_rounds <= dlt.sync_rounds <= full.sync_rounds + 2


def test_sequential_has_no_invalidations(llm):
    cfg, params = llm
    r = run_task(cfg, params, TASKS["dashboard"], mode="sequential", seed=2)
    assert r.invalidations == 0            # deps complete before each claim


def test_parallel_coupled_task_pays_coordination(llm):
    cfg, params = llm
    r = run_task(cfg, params, TASKS["dashboard"], mode="parallel",
                 n_agents=4, seed=2)
    assert r.invalidations > 0             # observation-driven re-prefills
    assert r.observation_events > 0        # O(N×U) accounting nonzero


def test_volume_inflation_applied(llm):
    cfg, params = llm
    seq = run_task(cfg, params, TASKS["visualizer"], mode="sequential", seed=3)
    par = run_task(cfg, params, TASKS["visualizer"], mode="parallel",
                   n_agents=4, seed=3)
    ratio = par.gen_tokens / seq.gen_tokens
    assert 2.0 < ratio < 3.5               # ~2.89x from paper Table 5


def test_low_coupling_parallel_speedup_steps(llm):
    """Paper Table 4 structure: decoupled tasks speed up in parallel."""
    cfg, params = llm
    seq = run_task(cfg, params, TASKS["tic_tac_toe"], mode="sequential",
                   seed=4)
    par = run_task(cfg, params, TASKS["tic_tac_toe"], mode="parallel",
                   n_agents=4, seed=4)
    assert par.steps < seq.steps


def test_normalized_time_favors_parallel(llm):
    """Paper Table 7 structure: per-token steps lower in parallel."""
    cfg, params = llm
    seq = run_task(cfg, params, TASKS["pomodoro"], mode="sequential", seed=5)
    par = run_task(cfg, params, TASKS["pomodoro"], mode="parallel",
                   n_agents=4, seed=5)
    assert par.steps_per_1k_tokens < seq.steps_per_1k_tokens


def test_determinism_same_seed(llm):
    cfg, params = llm
    a = run_task(cfg, params, TASKS["registration"], mode="parallel",
                 n_agents=3, seed=7)
    b = run_task(cfg, params, TASKS["registration"], mode="parallel",
                 n_agents=3, seed=7)
    assert a.digest == b.digest
    assert a.gen_tokens == b.gen_tokens
    assert a.steps == b.steps
