"""CacheSpec registry: layout routing, typed traversal, block-table
validation (the offending layer must be NAMED), and COW page copies."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import attention, lm
from repro.models import cache as cache_mod


@pytest.fixture(scope="module")
def olmo():
    return configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)


@pytest.fixture(scope="module")
def dsv2():
    return configs.reduced(configs.get("deepseek-v2-lite-16b"), d_model=32,
                           vocab=128)


def test_layout_routing(olmo, dsv2):
    assert cache_mod.layout_for("attn", olmo, paged=False) == "dense"
    assert cache_mod.layout_for("attn", olmo, paged=True) == "paged_mha"
    assert cache_mod.layout_for("local", olmo, paged=True) == "dense"
    assert cache_mod.layout_for("mla_moe", dsv2, paged=True) == "paged_mla"
    assert cache_mod.layout_for("rglru", olmo, paged=True) == "state"
    with pytest.raises(ValueError):
        cache_mod.layout_for("nope", olmo, paged=False)


def test_spec_init_matches_model_cache(olmo):
    """lm.init_cache is exactly the spec registry's init, group-stacked."""
    specs = lm.cache_specs(olmo, 2, 16, paged=True, page_size=8)
    cache = lm.init_cache(olmo, 2, 16, paged=True, page_size=8)
    spec = specs["groups"]["0"]
    assert spec.layout == "paged_mha" and spec.paged
    for leaf in spec.leaves:
        arr = cache["groups"]["0"][leaf.name]
        assert arr.shape == (olmo.pattern_groups,) + leaf.shape
        assert arr.dtype == leaf.dtype
    roles = {l.name: l.role for l in spec.leaves}
    assert roles == {"k_pages": "pool", "v_pages": "pool",
                     "block_tables": "table"}


def test_paged_mla_spec_pads_latent_width(dsv2):
    spec = cache_mod.spec_for("mla", dsv2, 2, 32, paged=True, page_size=8)
    m = dsv2.mla
    width = m.kv_lora_rank + m.rope_head_dim
    assert spec.latent_width == width
    pool = spec.leaf("latent_pages")
    assert pool.shape[-1] == cache_mod.pad128(width)
    assert pool.shape[-1] % 128 == 0


def test_layout_of_and_iter_layers(olmo):
    cache = lm.init_cache(olmo, 2, 16, paged=True, page_size=8)
    layers = list(cache_mod.iter_layers(cache))
    assert layers and all(layout == "paged_mha" for _, layout, _ in layers)
    assert cache_mod.layout_of({"k": 1, "v": 2}) == "dense"
    assert cache_mod.layout_of({"unknown": 1}) is None


def test_state_layout_preserves_module_init(olmo):
    """xLSTM's m-state inits to -10, not zero — spec must honor it."""
    cfg = olmo.replace(block_pattern=("mlstm",), num_layers=2)
    spec = cache_mod.spec_for("mlstm", cfg, 2, 16)
    state = spec.init()
    assert float(np.asarray(state["m"]).max()) == -10.0


# ---------------------------------------------------------------------------
# set_block_tables validation (satellite: name the offending layer)
# ---------------------------------------------------------------------------

def test_set_block_tables_validates_shape_and_names_layer(olmo):
    cache = lm.init_cache(olmo, 2, 16, paged=True, page_size=8)   # maxp = 2
    ok = attention.default_block_tables(2, 16, 8)
    cache = lm.set_block_tables(cache, ok)                        # fits

    with pytest.raises(ValueError, match=r"groups/0"):
        lm.set_block_tables(cache, jnp.zeros((2, 5), jnp.int32))  # bad maxp
    with pytest.raises(ValueError, match=r"expected \[B, maxp\]"):
        lm.set_block_tables(cache, jnp.zeros((4, 2), jnp.int32))  # bad batch


def test_set_block_tables_dense_noop(olmo):
    cache = lm.init_cache(olmo, 2, 16)
    out = lm.set_block_tables(cache, jnp.zeros((2, 99), jnp.int32))
    assert lm.get_block_tables(out) is None
    np.testing.assert_array_equal(np.asarray(out["groups"]["0"]["k"]),
                                  np.asarray(cache["groups"]["0"]["k"]))


# ---------------------------------------------------------------------------
# COW page copy
# ---------------------------------------------------------------------------

def test_copy_pages_duplicates_and_drops(olmo, dsv2):
    for cfg in (olmo, dsv2):
        cache = lm.init_cache(cfg, 2, 16, dtype=jnp.float32, paged=True,
                              page_size=8)
        # Fill pools with recognizable content.
        cache = jax.tree.map(
            lambda x: (jnp.arange(x.size, dtype=x.dtype).reshape(x.shape)
                       if x.dtype == jnp.float32 else x), cache)
        out = lm.copy_pages(cache, jnp.asarray([0, -1], jnp.int32),
                            jnp.asarray([3, 1], jnp.int32))
        for (_, layout, a), (_, _, b) in zip(cache_mod.iter_layers(cache),
                                             cache_mod.iter_layers(out)):
            for name in cache_mod.pool_leaves(a, layout):
                pa, pb = np.asarray(a[name]), np.asarray(b[name])
                stacked = pa.ndim == (5 if layout == "paged_mha" else 4)
                if stacked:
                    np.testing.assert_array_equal(pb[:, 3], pa[:, 0])
                    np.testing.assert_array_equal(pb[:, 1], pa[:, 1])  # drop
                else:
                    np.testing.assert_array_equal(pb[3], pa[0])
                    np.testing.assert_array_equal(pb[1], pa[1])
