"""Edge cases of merge._masked_pmax and rga.insert_run capacity overflow.

``_masked_pmax`` is the workhorse of the pmax merge strategy; its contract
has three documented subtleties that were previously untested:

  * invalid lanes contribute the dtype's neutral element (-inf / INT_MIN /
    False) so they never win,
  * lanes that NO replica has observed fall back to the (identical) local
    default, keeping the result bit-equal to the fold join,
  * payloads at the neutral sentinel itself alias that fallback — the
    documented precondition is that real payloads never carry the sentinel
    (tokens/clocks/lengths are >= -1); the test pins the aliasing behaviour
    so a future payload type that violates the precondition fails loudly.

Collectives run under ``jax.vmap(..., axis_name=...)`` — the single-process
stand-in for the replica mesh axis (the 8-device shard_map path is covered
by tests/test_distributed_merge.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import merge, rga

R = 4


def _run_masked_pmax(x, valid):
    """Apply _masked_pmax across a stacked replica axis [R, ...]."""
    fn = jax.vmap(lambda xi, vi: merge._masked_pmax(xi, vi, "r"),
                  axis_name="r")
    return np.asarray(fn(x, valid))


# ---------------------------------------------------------------------------
# _masked_pmax dtype paths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32, jnp.bool_])
def test_masked_pmax_valid_lanes_take_max(dtype):
    rng = np.random.default_rng(0)
    if dtype == jnp.bool_:
        x = jnp.asarray(rng.random((R, 8)) > 0.5)
    elif dtype == jnp.float32:
        x = jnp.asarray(rng.normal(size=(R, 8)), dtype)
    else:
        x = jnp.asarray(rng.integers(-50, 50, (R, 8)), dtype)
    valid = jnp.ones((R, 8), jnp.bool_)
    out = _run_masked_pmax(x, valid)
    want = np.asarray(jnp.max(x.astype(jnp.int32), axis=0).astype(dtype)
                      if dtype == jnp.bool_ else jnp.max(x, axis=0))
    for i in range(R):
        np.testing.assert_array_equal(out[i], want)


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_masked_pmax_single_winner_carries_payload(dtype):
    """Exactly one valid lane per position: the winner's payload is exact,
    even when it is negative (i.e. below every invalid lane's raw value)."""
    x = np.zeros((R, 4), np.float64)
    x[:, :] = 99.0                       # garbage on non-winners
    winners = [0, 1, 2, 3]
    for j, w in enumerate(winners):
        x[w, j] = -7.0 - j               # winner's payload, negative
    xv = jnp.asarray(x, dtype)
    valid = jnp.asarray([[w == i for j, w in enumerate(winners)]
                         for i in range(R)])
    out = _run_masked_pmax(xv, valid)
    for i in range(R):
        np.testing.assert_array_equal(
            out[i], np.asarray([-7.0, -8.0, -9.0, -10.0],
                               np.asarray(xv).dtype))


def test_masked_pmax_all_invalid_keeps_local_default():
    """Lanes no replica observed keep the (identical) local default — the
    bit-equal-to-fold-join guarantee for unobserved state."""
    default = 3
    x = jnp.full((R, 6), default, jnp.int32)
    valid = jnp.zeros((R, 6), jnp.bool_)
    out = _run_masked_pmax(x, valid)
    np.testing.assert_array_equal(out, np.full((R, 6), default))
    # float path
    xf = jnp.full((R, 6), 0.5, jnp.float32)
    outf = _run_masked_pmax(xf, valid)
    np.testing.assert_array_equal(outf, np.full((R, 6), 0.5, np.float32))
    # bool path: OR of all-False masked lanes stays False
    xb = jnp.zeros((R, 6), jnp.bool_)
    outb = _run_masked_pmax(xb, valid)
    assert not outb.any()


def test_masked_pmax_payload_at_neutral_sentinel_aliases_local():
    """A valid payload AT the sentinel (INT32_MIN / -inf) is indistinguishable
    from 'nobody observed this lane': every replica keeps its local value.
    This pins the documented precondition (payloads are >= -1) — if a payload
    type ever carries the sentinel, replicas may diverge exactly here."""
    sentinel = np.iinfo(np.int32).min
    x = np.full((R, 2), 5, np.int32)
    x[1, 0] = sentinel                   # replica 1's "real" payload
    valid = np.zeros((R, 2), bool)
    valid[1, :] = True
    out = _run_masked_pmax(jnp.asarray(x), jnp.asarray(valid))
    # Lane 1 (payload 5, observed) propagates; lane 0 (payload == sentinel)
    # aliases the unobserved fallback: each replica keeps its own local x.
    np.testing.assert_array_equal(out[:, 1], np.full((R,), 5))
    np.testing.assert_array_equal(out[:, 0], x[:, 0])

    xf = np.full((R, 2), 1.0, np.float32)
    xf[2, 0] = -np.inf
    validf = np.zeros((R, 2), bool)
    validf[2, :] = True
    outf = _run_masked_pmax(jnp.asarray(xf), jnp.asarray(validf))
    np.testing.assert_array_equal(outf[:, 0], xf[:, 0])


def test_masked_pmax_trailing_payload_dims_broadcast():
    """valid is [R, K]; payloads may be [R, K, D] (LWW payload fields)."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 50, (R, 3, 5)), jnp.int32)
    valid = jnp.asarray([[True, False, False]] * R)
    valid = valid.at[2, 1].set(True)
    out = _run_masked_pmax(x, valid)
    want0 = np.max(np.asarray(x)[:, 0], axis=0)
    for i in range(R):
        np.testing.assert_array_equal(out[i, 0], want0)       # all valid: max
        np.testing.assert_array_equal(out[i, 1], np.asarray(x)[2, 1])  # one
        np.testing.assert_array_equal(out[i, 2], np.asarray(x)[i, 2])  # none


# ---------------------------------------------------------------------------
# rga.insert_run capacity overflow
# ---------------------------------------------------------------------------


def test_insert_run_truncates_at_capacity():
    cap = 8
    s = rga.empty(3, cap)
    s = rga.insert_run(s, 1, 1, s.head_oid,
                       jnp.asarray(np.arange(1, 7, dtype=np.int32)), 6)
    assert int(s.count[1]) == 6
    # Second run of 6 only has room for 2.
    s = rga.insert_run(s, 1, 7, jnp.int32(1 * cap + 5),
                       jnp.asarray(np.arange(10, 16, dtype=np.int32)), 6)
    assert int(s.count[1]) == cap
    toks, oids, n = rga.materialize(s)
    assert int(n) == cap
    np.testing.assert_array_equal(
        np.asarray(toks[:cap]), [1, 2, 3, 4, 5, 6, 10, 11])


def test_insert_run_overflow_merge_no_duplicate_oids():
    """Truncated runs must still merge and materialize with unique oids."""
    cap = 8
    base = rga.empty(3, cap)
    a = rga.insert_run(base, 1, 1, base.head_oid,
                       jnp.asarray(np.arange(1, 11, dtype=np.int32)[:8]), 8)
    a = rga.insert_run(a, 1, 9, jnp.int32(1 * cap + 7),
                       jnp.asarray([91, 92, 93, 94]), 4)   # fully dropped
    b = rga.insert_run(base, 2, 1, base.head_oid,
                       jnp.asarray([51, 52, 53, 54]), 4)
    m1 = rga.merge(a, b)
    m2 = rga.merge(b, a)
    toks1, oids1, n1 = rga.materialize(m1)
    toks2, oids2, n2 = rga.materialize(m2)
    assert int(n1) == int(n2) == int(jnp.sum(m1.count)) == 12
    np.testing.assert_array_equal(np.asarray(toks1), np.asarray(toks2))
    ids = np.asarray(oids1[: int(n1)])
    assert len(set(ids.tolist())) == int(n1), "duplicate oids after overflow"


def test_insert_run_overflow_zero_room():
    """A run inserted into a full row is a no-op (no wraparound writes)."""
    cap = 4
    s = rga.empty(2, cap)
    s = rga.insert_run(s, 1, 1, s.head_oid, jnp.asarray([1, 2, 3, 4]), 4)
    before = jax.tree.map(np.asarray, s)
    s2 = rga.insert_run(s, 1, 5, jnp.int32(1 * cap + 3),
                        jnp.asarray([9, 9, 9, 9]), 4)
    for x, y in zip(jax.tree.leaves(before), jax.tree.leaves(
            jax.tree.map(np.asarray, s2))):
        np.testing.assert_array_equal(x, y)
