"""Runtime tests: checkpointing (atomic, async, GC, validation), elastic
CRDT work queue (claims, reclaim, stragglers), and the fault-tolerant
trainer (crash → reclaim → restore → identical convergence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.data.pipeline import DataConfig, shard_batches
from repro.runtime import checkpoint as ck
from repro.runtime.elastic import Worker, make_queue, make_shared_fold_sync
from repro.training.trainer import Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 3)),
            "b": {"c": jnp.arange(7, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ck.save(tmp_path, 5, t)
    restored, step = ck.restore(tmp_path, t)
    assert step == 5
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_latest_pointer_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        ck.save(tmp_path, s, t, keep=2)
    assert ck.latest_step(tmp_path) == 4
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000003", "step_00000004"]


def test_checkpoint_structure_validation(tmp_path):
    ck.save(tmp_path, 1, _tree())
    with pytest.raises(ValueError):
        ck.restore(tmp_path, {"a": jnp.zeros((4, 3))})     # missing leaf
    with pytest.raises(ValueError):
        bad = _tree()
        bad["a"] = jnp.zeros((5, 3))                       # wrong shape
        ck.restore(tmp_path, bad)


def test_async_checkpointer_overlap(tmp_path):
    acp = ck.AsyncCheckpointer(tmp_path, keep=3)
    for s in (10, 20):
        acp.save(s, _tree(s))
    acp.wait()
    assert ck.latest_step(tmp_path) == 20


def test_partial_write_never_corrupts_latest(tmp_path):
    t = _tree()
    ck.save(tmp_path, 1, t)
    # Simulate a crashed write: tmp dir left behind.
    (tmp_path / ".tmp_step_00000002").mkdir()
    assert ck.latest_step(tmp_path) == 1
    restored, step = ck.restore(tmp_path, t)
    assert step == 1


# ---------------------------------------------------------------------------
# Elastic work queue
# ---------------------------------------------------------------------------

def test_two_workers_drain_queue_disjointly():
    shared = {}
    sync = make_shared_fold_sync(shared)
    q = make_queue(num_shards=6, num_workers=2)
    w1, w2 = Worker(1, q, sync), Worker(2, q, sync)
    got = {1: [], 2: []}
    for t in range(40):
        for w in (w1, w2):
            w.heartbeat(t)
            s = w.try_claim_shard(t)
            if s is not None:
                got[w.id].append(s)
                w.complete_shard(s)
        if w1.done() and w2.done():
            break
    assert sorted(got[1] + got[2]) == list(range(6))
    assert not (set(got[1]) & set(got[2]))          # no duplicated shards


def test_dead_worker_shard_reclaimed():
    shared = {}
    sync = make_shared_fold_sync(shared)
    q = make_queue(num_shards=2, num_workers=2)
    w1, w2 = Worker(1, q, sync, stale_timeout=50), Worker(2, q, sync,
                                                          stale_timeout=50)
    s1 = w1.try_claim_shard(0)
    assert s1 is not None
    # w1 dies.  w2 proceeds; before timeout the shard is locked.
    s2 = w2.try_claim_shard(1)
    if s2 is not None:
        w2.complete_shard(s2)
    assert w2.try_claim_shard(2) is None
    # After the timeout w2 reclaims and finishes w1's shard.
    assert w2.reclaim_stale(100) >= 1
    s3 = w2.try_claim_shard(101)
    assert s3 == s1
    w2.complete_shard(s3)
    assert w2.done()


def test_straggler_detection():
    shared = {}
    sync = make_shared_fold_sync(shared)
    q = make_queue(4, 3)
    w1, w2 = Worker(1, q, sync), Worker(2, q, sync)
    w1.heartbeat(100)
    w2.heartbeat(10)     # lagging
    assert w1.stragglers(now=100, lag=50) == [2]


def test_elastic_join_mid_run():
    shared = {}
    sync = make_shared_fold_sync(shared)
    q = make_queue(num_shards=5, num_workers=4)
    w1 = Worker(1, q, sync)
    done = []
    s = w1.try_claim_shard(0)
    done.append(s)
    w1.complete_shard(s)
    # New worker joins with the *merged* state (observation-driven join).
    w3 = Worker(3, w1.state, sync)
    for t in range(1, 20):
        for w in (w1, w3):
            sh = w.try_claim_shard(t)
            if sh is not None:
                done.append(sh)
                w.complete_shard(sh)
        if w1.done():
            break
    assert sorted(done) == list(range(5))


# ---------------------------------------------------------------------------
# Fault-tolerant trainer
# ---------------------------------------------------------------------------

def _tiny_setup(tmp_path, steps=12):
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2,
                    shard_size_batches=2)
    tc = TrainerConfig(steps=steps, checkpoint_every=4,
                       checkpoint_dir=str(tmp_path), shard_timeout=50)
    return cfg, dc, tc


def test_trainer_runs_and_loss_finite(tmp_path):
    cfg, dc, tc = _tiny_setup(tmp_path)
    shared = {}
    q = make_queue(num_shards=8, num_workers=1)
    w = Worker(1, q, make_shared_fold_sync(shared))
    tr = Trainer(cfg, dc, tc)
    out = tr.run(w, now_fn=lambda: 0)
    assert not out["crashed"]
    assert out["step"] == tc.steps
    losses = [m["loss"] for m in out["metrics"]]
    assert all(np.isfinite(losses))


def test_crash_restart_resumes_from_checkpoint(tmp_path):
    cfg, dc, tc = _tiny_setup(tmp_path, steps=10)
    shared = {}
    sync = make_shared_fold_sync(shared)
    q = make_queue(num_shards=6, num_workers=2)

    w1 = Worker(1, q, sync, stale_timeout=50)
    t1 = Trainer(cfg, dc, tc)
    out1 = t1.run(w1, now_fn=lambda: 0, fail_after_steps=5)
    assert out1["crashed"] and out1["step"] == 5
    # Crash model: the step-4 checkpoint had committed before the crash (a
    # half-written one is equivalent to an older committed one — the atomic
    # rename tests cover that); flush the async writer so restore is
    # deterministic under suite load.
    t1.ckpt.wait()

    # Survivor restores the checkpoint, reclaims the stale shard, finishes.
    w2 = Worker(2, shared["state"], sync, stale_timeout=50)
    t2 = Trainer(cfg, dc, tc)
    assert t2.maybe_restore()
    assert t2.step == 4                      # last checkpoint before crash
    out2 = t2.run(w2, now_fn=lambda: 1000)   # past the stale timeout
    assert not out2["crashed"]
    assert out2["step"] == tc.steps


def test_reclaimed_shard_data_is_deterministic():
    dc = DataConfig(vocab_size=97, seq_len=12, batch_size=2,
                    shard_size_batches=3)
    a = shard_batches(dc, 4)
    b = shard_batches(dc, 4)
    for x, y in zip(a, b):
        for k in x:
            np.testing.assert_array_equal(x[k], y[k])
