"""Paged MLA latent cache: kernel-vs-oracle sweep, bit-for-bit dense
equivalence, ragged latent prefill isolation, and the full pipeline.

The acceptance bar: the paged MLA decode's gather oracle must match the
dense MLA decode BIT-FOR-BIT in interpret mode across a page-size × batch
sweep (same einsum order, same fp32 promotion, masked lanes contribute
exact zeros), and greedy token streams must agree on every path.  The
Pallas kernel (online softmax) is held to tight f32 tolerance plus exact
argmax agreement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.kernels import ops, ref
from repro.models import attention, lm, mla
from repro.models import cache as cache_mod


def _f32(t):
    return jax.tree.map(lambda x: x.astype(jnp.float32), t)


@pytest.fixture(scope="module")
def mla_llm():
    cfg = configs.reduced(configs.get("deepseek-v2-lite-16b"), d_model=32,
                          vocab=128)
    return cfg, _f32(lm.init(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Kernel vs gather oracle (page-size × batch sweep)
# ---------------------------------------------------------------------------

def _setup(b, h, r, rd, ps, maxp, seed=0):
    rng = np.random.default_rng(seed)
    pool_n = b * maxp + 2                     # spare pages stay untouched
    dp = cache_mod.pad128(r + rd)
    q_abs = jnp.asarray(rng.normal(size=(b, h, r)), jnp.float32)
    q_rope = jnp.asarray(rng.normal(size=(b, h, rd)), jnp.float32)
    pool = jnp.asarray(rng.normal(size=(pool_n, ps, dp)), jnp.float32)
    bt = jnp.asarray(rng.permutation(pool_n)[:b * maxp].reshape(b, maxp)
                     .astype(np.int32))
    pos = jnp.asarray(rng.integers(0, maxp * ps, b), jnp.int32)
    lat_new = jnp.asarray(rng.normal(size=(b, dp)), jnp.float32)
    return q_abs, q_rope, pool, bt, pos, lat_new


@pytest.mark.parametrize("b,ps,maxp", [(1, 4, 3), (2, 8, 2), (3, 16, 4),
                                       (4, 8, 5)])
def test_paged_mla_kernel_matches_oracle_sweep(b, ps, maxp):
    h, r, rd = 4, 32, 8
    q_abs, q_rope, pool, bt, pos, lat = _setup(b, h, r, rd, ps, maxp)
    scale = 0.11
    o_ref, pool_ref = ref.paged_mla_decode(q_abs, q_rope, pool, bt, pos,
                                           lat, r=r, scale=scale)
    o_k, pool_k = ops.paged_mla_decode(q_abs, q_rope, pool, bt, pos, lat,
                                       scale=scale, use_pallas=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_k),
                               rtol=2e-5, atol=2e-5)
    # The fused write must land identically: pools match exactly.
    np.testing.assert_array_equal(np.asarray(pool_ref), np.asarray(pool_k))


def test_paged_mla_unallocated_row_drops_write():
    b, h, r, rd, ps, maxp = 2, 4, 32, 8, 8, 2
    q_abs, q_rope, pool, bt, pos, lat = _setup(b, h, r, rd, ps, maxp, seed=3)
    bt = jnp.full_like(bt, -1)                # no row owns any page
    o1, p1 = ops.paged_mla_decode(q_abs, q_rope, pool, bt, pos, lat,
                                  scale=0.1, use_pallas=True)
    o2, p2 = ref.paged_mla_decode(q_abs, q_rope, pool, bt, pos, lat,
                                  r=r, scale=0.1)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(pool))


# ---------------------------------------------------------------------------
# Bit-for-bit: paged MLA decode (oracle path) == dense MLA decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ps,batch", [(4, 1), (4, 3), (8, 2), (8, 3),
                                      (16, 2), (16, 3)])
def test_paged_mla_decode_bitwise_matches_dense_sweep(mla_llm, ps, batch):
    """max_len % ps == 0 so the gathered stream has the dense extent —
    identical reduction shapes, identical bits."""
    cfg, params = mla_llm
    max_len = 32
    p = _f32(mla.init(jax.random.PRNGKey(1), cfg))
    rng = np.random.default_rng(ps * 100 + batch)
    t0 = 5
    x_pre = jnp.asarray(rng.normal(size=(batch, t0, cfg.d_model)),
                        jnp.float32)
    mask = jnp.tril(jnp.ones((t0, t0), bool))

    dense = mla.init_cache(cfg, batch, max_len, dtype=jnp.float32)
    paged = mla.init_cache(cfg, batch, max_len, dtype=jnp.float32,
                           paged=True, page_size=ps)
    paged = dict(paged, block_tables=attention.default_block_tables(
        batch, max_len, ps))
    yd, dense = mla.prefill(p, cfg, x_pre, dense, mask, jnp.arange(t0))
    yp, paged = mla.prefill(p, cfg, x_pre, paged, mask, jnp.arange(t0))
    np.testing.assert_array_equal(np.asarray(yd), np.asarray(yp))

    pos = jnp.full((batch,), t0, jnp.int32)
    for step in range(6):
        x = jnp.asarray(rng.normal(size=(batch, 1, cfg.d_model)), jnp.float32)
        od, dense = mla.decode_step(p, cfg, x, dense, pos)
        op, paged = mla.decode_step(p, cfg, x, paged, pos)
        np.testing.assert_array_equal(np.asarray(od), np.asarray(op)), step
        pos = pos + 1


def test_paged_mla_pipeline_matches_dense(mla_llm):
    """Full LM pipeline (ragged prefill -> greedy decode): exact tokens on
    both the oracle and the interpret-mode Pallas path."""
    cfg, params = mla_llm
    B, MAX_LEN, PS = 3, 32, 8
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(2, 100, (B, 8)), jnp.int32)
    lengths = jnp.asarray([8, 3, 5], jnp.int32)

    def run(cache, impl):
        logits, cache = lm.prefill(params, cfg, prompts, cache, impl=impl,
                                   lengths=lengths)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = lengths
        out = [np.asarray(tok)]
        for _ in range(10):
            logits, cache = lm.decode_step(params, cfg, tok, cache, pos,
                                           impl=impl)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            pos = pos + 1
            out.append(np.asarray(tok))
        return np.stack(out, 1), np.asarray(logits)

    dense = lm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32)
    toks_d, logits_d = run(dense, "ref")

    def paged_cache():
        c = lm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32, paged=True,
                          page_size=PS)
        return lm.set_block_tables(
            c, attention.default_block_tables(B, MAX_LEN, PS))

    toks_p, logits_p = run(paged_cache(), "ref")
    np.testing.assert_array_equal(toks_d, toks_p)
    np.testing.assert_array_equal(logits_d, logits_p)   # bit-for-bit

    toks_k, logits_k = run(paged_cache(), "pallas")
    np.testing.assert_array_equal(toks_d, toks_k)
    np.testing.assert_allclose(logits_d, logits_k, rtol=2e-4, atol=2e-4)


def test_mla_ragged_prefill_preserves_untouched_rows(mla_llm):
    """lengths[b] == 0 rows keep their latent pages bit-for-bit."""
    cfg, params = mla_llm
    B, MAX_LEN, PS = 3, 32, 8
    rng = np.random.default_rng(2)
    prompts = jnp.asarray(rng.integers(2, 100, (B, 8)), jnp.int32)
    cache = lm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32, paged=True,
                          page_size=PS)
    cache = lm.set_block_tables(
        cache, attention.default_block_tables(B, MAX_LEN, PS))
    _, cache = lm.prefill(params, cfg, prompts, cache,
                          lengths=jnp.asarray([6, 0, 0], jnp.int32))
    bt = np.asarray(lm.get_block_tables(cache))
    pool_before = np.asarray(cache["groups"]["0"]["latent_pages"]).copy()
    _, cache = lm.prefill(params, cfg, prompts, cache,
                          lengths=jnp.asarray([0, 8, 0], jnp.int32))
    pool_after = np.asarray(cache["groups"]["0"]["latent_pages"])
    others = [p for p in range(pool_before.shape[1])
              if p not in set(bt[1].tolist())]
    np.testing.assert_array_equal(pool_before[:, others],
                                  pool_after[:, others])


def test_mla_scheduler_paged_dense_agree(mla_llm):
    """Continuous batching over an MLA model: paged == dense token streams
    (universal paging — the scheduler no longer cares about the layout)."""
    from repro.serving.scheduler import ContinuousBatchingEngine, Request
    cfg, params = mla_llm
    spec = [(5, 4), (9, 3), (3, 5), (7, 2)]
    outs = {}
    for mode in ("paged", "dense"):
        rng = np.random.default_rng(7)
        reqs = [Request(rid=i,
                        prompt=[int(t) for t in rng.integers(2, 100, n)],
                        max_new_tokens=m)
                for i, (n, m) in enumerate(spec)]
        eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                       paged=(mode == "paged"), page_size=8)
        outs[mode] = eng.run(reqs)
        assert eng.stats["completed"] == len(spec)
    for got, want in zip(outs["paged"], outs["dense"]):
        assert got.tokens == want.tokens, got.rid
