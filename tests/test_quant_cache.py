"""Quantized page pools + tiered host-swap page memory.

Covers the quantized cache layouts end to end:

  * quant/dequant roundtrip properties — scale is never zero (all-zero
    rows quantize against scale 1.0) and int8 round-to-nearest bounds the
    per-element error by scale/2 (fp8 e4m3 by the half-ulp relative bound),
  * fused quant kernels vs the ``kernels.ref`` gather oracles — pool and
    scale writes bitwise identical between the Pallas(interpret) and ref
    paths, attention outputs tight,
  * snapshot_span/restore_span and host-swap-pool roundtrips bitwise on
    quantized pools INCLUDING the scale leaves (the generic page machinery
    iterates _POOL_LEAF_NDIM, so scales must travel with their pages),
  * the serving stack under ``kv_quant="int8"``: greedy streams identical
    to bf16 pools, COW prefix sharing, speculative rollback, replication,
  * tiered memory: swap-preemption recovers a long-context victim in fewer
    steps than recompute-from-scratch with identical streams and no leaked
    swap slots or device pages.

Mirrors tests/test_allocator_properties.py's optional-hypothesis pattern:
explicit seed parameters always run; when ``hypothesis`` is installed (the
CI property job) the roundtrip bounds are additionally driven by generated
inputs.  Tier-1 collects and passes without the package.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.kernels import ops, ref
from repro.models import attention, cache as cache_mod, lm
from repro.serving.scheduler import ContinuousBatchingEngine, Request

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

QMODES = [m for m in ("int8", "fp8")
          if m != "fp8" or cache_mod.FP8_DTYPE is not None]


def _qdtype(mode: str):
    return jnp.int8 if mode == "int8" else cache_mod.FP8_DTYPE


def _f32(params):
    return jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def llm():
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    return cfg, _f32(lm.init(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Quant/dequant roundtrip properties
# ---------------------------------------------------------------------------

def _check_roundtrip(x: jax.Array, mode: str) -> None:
    q, s = ref.quantize_rows(x, _qdtype(mode))
    sn = np.asarray(s)
    assert np.all(sn > 0), "scale must never be zero"
    xf = np.asarray(x, np.float32)
    err = np.abs(np.asarray(ref.dequantize_rows(q, s)) - xf)
    if mode == "int8":
        # symmetric round-to-nearest: |x - q*scale| <= scale/2
        bound = sn[..., None] * 0.5 * (1 + 1e-5) + 1e-7
    else:
        # e4m3 (~3 mantissa bits): half-ulp relative error 2^-4 for
        # normals, plus the denormal floor in q units.
        bound = np.abs(xf) * 2.0 ** -4 + sn[..., None] * 2.0 ** -9 + 1e-7
    assert np.all(err <= bound), f"max err {err.max()} over bound ({mode})"


@pytest.mark.parametrize("mode", QMODES)
@pytest.mark.parametrize("seed", range(8))
def test_quantize_rows_roundtrip_bounds(mode, seed):
    rng = np.random.default_rng(seed)
    d = int(2 ** rng.integers(2, 7))
    mag = float(2.0 ** rng.uniform(-6, 6))
    x = jnp.asarray(rng.normal(0.0, mag, (3, 5, d)), jnp.float32)
    _check_roundtrip(x, mode)


@pytest.mark.parametrize("mode", QMODES)
def test_quantize_all_zero_rows_scale_one(mode):
    q, s = ref.quantize_rows(jnp.zeros((4, 8), jnp.float32), _qdtype(mode))
    assert np.all(np.asarray(s) == 1.0)
    assert np.all(np.asarray(q.astype(jnp.float32)) == 0.0)


@pytest.mark.parametrize("mode", QMODES)
def test_quantize_mixed_zero_and_huge_rows(mode):
    x = jnp.stack([jnp.zeros((16,)), jnp.full((16,), 3e4),
                   jnp.full((16,), -1e-6)]).astype(jnp.float32)
    _check_roundtrip(x, mode)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 2 ** 31 - 1), mi=st.integers(0, 7),
           logmag=st.floats(-8, 8), d=st.integers(1, 48))
    def test_quantize_rows_roundtrip_hypothesis(seed, mi, logmag, d):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(0.0, 2.0 ** logmag, (2, 3, d)),
                        jnp.float32)
        _check_roundtrip(x, QMODES[mi % len(QMODES)])


# ---------------------------------------------------------------------------
# Fused quant kernels vs ref oracles (interpret mode on CPU)
# ---------------------------------------------------------------------------

def _mha_quant_pool(rng, mode, P=6, Hkv=2, ps=8, D=16):
    qd = _qdtype(mode)
    kq, ks = ref.quantize_rows(
        jnp.asarray(rng.normal(0, 1, (P, Hkv, ps, D)), jnp.float32), qd)
    vq, vs = ref.quantize_rows(
        jnp.asarray(rng.normal(0, 1, (P, Hkv, ps, D)), jnp.float32), qd)
    return kq, ks, vq, vs


@pytest.mark.parametrize("mode", QMODES)
def test_paged_decode_quant_kernel_matches_oracle(mode):
    rng = np.random.default_rng(3)
    B, Hq, Hkv, D, ps, maxp = 2, 4, 2, 16, 8, 3
    kq, ks, vq, vs = _mha_quant_pool(rng, mode, P=B * maxp, Hkv=Hkv, ps=ps,
                                     D=D)
    bt = jnp.arange(B * maxp, dtype=jnp.int32).reshape(B, maxp)
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, D)), jnp.float32)
    pos = jnp.asarray([5, 13], jnp.int32)
    kn = jnp.asarray(rng.normal(0, 1, (B, Hkv, D)), jnp.float32)
    vn = jnp.asarray(rng.normal(0, 1, (B, Hkv, D)), jnp.float32)
    o1, kp1, vp1, ks1, vs1 = ops.paged_decode_attention_quant(
        q, kq, ks, vq, vs, bt, pos, kn, vn, use_pallas=True)
    o2, kp2, vp2, ks2, vs2 = ops.paged_decode_attention_quant(
        q, kq, ks, vq, vs, bt, pos, kn, vn, use_pallas=False)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=1e-4)
    for a, b in ((kp1, kp2), (vp1, vp2), (ks1, ks2), (vs1, vs2)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


@pytest.mark.parametrize("mode", QMODES)
def test_paged_chunk_quant_kernel_matches_oracle(mode):
    rng = np.random.default_rng(4)
    B, Hq, Hkv, D, ps, maxp, C = 2, 2, 1, 16, 8, 4, 6
    kq, ks, vq, vs = _mha_quant_pool(rng, mode, P=B * maxp, Hkv=Hkv, ps=ps,
                                     D=D)
    bt = jnp.arange(B * maxp, dtype=jnp.int32).reshape(B, maxp)
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, C, D)), jnp.float32)
    start = jnp.asarray([3, 11], jnp.int32)
    span = jnp.asarray([6, 4], jnp.int32)
    kn = jnp.asarray(rng.normal(0, 1, (B, Hkv, C, D)), jnp.float32)
    vn = jnp.asarray(rng.normal(0, 1, (B, Hkv, C, D)), jnp.float32)
    o1, kp1, vp1, ks1, vs1 = ops.paged_chunk_attention_quant(
        q, kq, ks, vq, vs, bt, start, span, kn, vn, use_pallas=True)
    o2, kp2, vp2, ks2, vs2 = ops.paged_chunk_attention_quant(
        q, kq, ks, vq, vs, bt, start, span, kn, vn, use_pallas=False)
    # Lanes past a row's span are garbage on both paths; compare valid ones.
    for b_i in range(B):
        w = int(span[b_i])
        np.testing.assert_allclose(
            np.asarray(o1[b_i, :, :w], np.float32),
            np.asarray(o2[b_i, :, :w], np.float32), atol=1e-4)
    for a, b in ((kp1, kp2), (vp1, vp2), (ks1, ks2), (vs1, vs2)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


@pytest.mark.parametrize("mode", QMODES)
def test_paged_mla_decode_quant_kernel_matches_oracle(mode):
    rng = np.random.default_rng(5)
    B, Hq, r, rd, ps, maxp = 2, 2, 16, 8, 8, 3
    dp = cache_mod.pad128(r + rd)
    pool, scales = ref.quantize_rows(
        jnp.asarray(rng.normal(0, 1, (B * maxp, ps, dp)), jnp.float32),
        _qdtype(mode))
    bt = jnp.arange(B * maxp, dtype=jnp.int32).reshape(B, maxp)
    q_abs = jnp.asarray(rng.normal(0, 1, (B, Hq, r)), jnp.float32)
    q_rope = jnp.asarray(rng.normal(0, 1, (B, Hq, rd)), jnp.float32)
    pos = jnp.asarray([4, 12], jnp.int32)
    lat = jnp.asarray(rng.normal(0, 1, (B, dp)), jnp.float32)
    sc = 1.0 / ((r + rd) ** 0.5)
    c1, p1, s1 = ops.paged_mla_decode_quant(q_abs, q_rope, pool, scales, bt,
                                            pos, lat, scale=sc,
                                            use_pallas=True)
    c2, p2, s2 = ops.paged_mla_decode_quant(q_abs, q_rope, pool, scales, bt,
                                            pos, lat, scale=sc,
                                            use_pallas=False)
    np.testing.assert_allclose(np.asarray(c1, np.float32),
                               np.asarray(c2, np.float32), atol=1e-4)
    assert np.asarray(p1).tobytes() == np.asarray(p2).tobytes()
    assert np.asarray(s1).tobytes() == np.asarray(s2).tobytes()


# ---------------------------------------------------------------------------
# Snapshot/restore + host-swap roundtrips, bitwise on quantized pools
# ---------------------------------------------------------------------------

def _pool_leaf_bytes(cache):
    """{(path, leaf): raw bytes} for every paged pool/scale leaf."""
    out = {}
    for path, layout, layer in cache_mod.iter_layers(cache):
        for name in cache_mod.pool_leaves(layer, layout):
            out[path + (name,)] = np.asarray(layer[name]).tobytes()
    return out


def _quant_prefilled(cfg, params, mode, batch=2, max_len=32, ps=8, plen=10,
                     seed=0):
    rng = np.random.default_rng(seed)
    cache = lm.init_cache(cfg, batch, max_len, dtype=jnp.float32,
                          paged=True, page_size=ps, kv_quant=mode)
    cache = lm.set_block_tables(
        cache, attention.default_block_tables(batch, max_len, ps))
    prompts = jnp.asarray(rng.integers(2, cfg.vocab_size, (batch, plen)),
                          jnp.int32)
    _, cache = lm.prefill(params, cfg, prompts, cache)
    return cache, plen


@pytest.mark.parametrize("mode", QMODES)
def test_snapshot_restore_span_bitwise_on_quant_pools(llm, mode):
    cfg, params = llm
    cache, plen = _quant_prefilled(cfg, params, mode)
    batch, width = 2, 4
    start = jnp.full((batch,), plen, jnp.int32)
    before = _pool_leaf_bytes(cache)
    snap = cache_mod.snapshot_span(cache, start, width)
    # Clobber slots inside the window with real decode writes.
    tok = jnp.asarray([7, 9], jnp.int32)
    clob = cache
    for t in range(2):
        _, clob = lm.decode_step(params, cfg, tok + t, clob,
                                 start + t)
    assert _pool_leaf_bytes(clob) != before
    back = cache_mod.restore_span(clob, snap, start, start, start + width)
    assert _pool_leaf_bytes(back) == before


@pytest.mark.parametrize("mode", QMODES)
def test_swap_pool_roundtrip_bitwise_on_quant_pools(llm, mode):
    cfg, params = llm
    cache, _ = _quant_prefilled(cfg, params, mode)
    pages, slots = [0, 1, 3], [2, 0, 1]
    before = _pool_leaf_bytes(cache)
    swap_pool = cache_mod.make_swap_pool(cache, n_slots=4)
    moved = cache_mod.swap_out_pages(cache, swap_pool, pages, slots)
    assert moved > 0

    def zero_pages(path, layout, layer):
        out = dict(layer)
        for name in cache_mod.pool_leaves(layer, layout):
            leaf = layer[name]
            core = cache_mod._POOL_LEAF_NDIM[layout][name]
            idx = jnp.asarray(pages)
            out[name] = (leaf.at[:, idx].set(0) if leaf.ndim == core + 1
                         else leaf.at[idx].set(0))
        return out

    clob = cache_mod.map_layers(cache, zero_pages)
    assert _pool_leaf_bytes(clob) != before
    back = cache_mod.swap_in_pages(clob, swap_pool, slots, pages)
    assert _pool_leaf_bytes(back) == before


# ---------------------------------------------------------------------------
# Cross-pool page movement (disaggregation transfer primitive)
# ---------------------------------------------------------------------------

def _page_rows(cache, pages):
    """{(path, leaf): raw bytes} of the given pool pages — scale rows travel
    with their payload rows, so a quantized page is only 'moved' when BOTH
    land bitwise."""
    out = {}
    for path, layout, layer in cache_mod.iter_layers(cache):
        for name in cache_mod.pool_leaves(layer, layout):
            leaf = np.asarray(layer[name])
            core = cache_mod._POOL_LEAF_NDIM[layout][name]
            rows = leaf[:, pages] if leaf.ndim == core + 1 else leaf[pages]
            out[path + (name,)] = rows.tobytes()
    return out


@pytest.mark.parametrize("mode", QMODES)
def test_copy_pages_across_distinct_quant_pools_bitwise(llm, mode):
    """The disaggregation data plane: pool rows AND scale rows of a
    quantized page land bitwise in a DIFFERENT engine's pool, and every
    untouched destination page keeps its prior bytes."""
    cfg, params = llm
    src, _ = _quant_prefilled(cfg, params, mode)
    dst, _ = _quant_prefilled(cfg, params, mode, seed=9)
    src_ids, dst_ids = [0, 4], [2, 6]
    assert _page_rows(src, src_ids) != _page_rows(dst, dst_ids)
    newdst, moved = cache_mod.copy_pages_across(src, dst, src_ids, dst_ids)
    assert moved > 0
    assert _page_rows(newdst, dst_ids) == _page_rows(src, src_ids)
    others = [p for p in range(8) if p not in dst_ids]
    assert _page_rows(newdst, others) == _page_rows(dst, others)


@pytest.mark.parametrize("mode", QMODES)
def test_export_adopt_roundtrip_quant_bitwise(llm, mode):
    """Host-transport half (export on the prefill side, adopt on the
    decode side) moves quantized pages bitwise across distinct pools."""
    cfg, params = llm
    src, _ = _quant_prefilled(cfg, params, mode)
    dst, _ = _quant_prefilled(cfg, params, mode, seed=9)
    rows = cache_mod.export_pages(src, [1, 5])
    newdst = cache_mod.adopt_pages(dst, rows, [3, 7])
    assert _page_rows(newdst, [3, 7]) == _page_rows(src, [1, 5])
    untouched = [p for p in range(8) if p not in (3, 7)]
    assert _page_rows(newdst, untouched) == _page_rows(dst, untouched)


def test_copy_pages_across_mismatch_names_layer_and_shapes(llm):
    """A pool-leaf mismatch fails loudly with the layer path, layout and
    both shapes — not deep inside a kernel call."""
    cfg, params = llm
    src, _ = _quant_prefilled(cfg, params, "int8", ps=8)
    dst, _ = _quant_prefilled(cfg, params, "int8", ps=16)
    with pytest.raises(ValueError,
                       match=r"pool leaf '.*' of layer .* does not match"):
        cache_mod.copy_pages_across(src, dst, [0])


def test_adopt_pages_mismatch_names_layer_and_shapes(llm):
    cfg, params = llm
    src, _ = _quant_prefilled(cfg, params, "int8", ps=8)
    dst, _ = _quant_prefilled(cfg, params, "int8", ps=16)
    rows = cache_mod.export_pages(src, [0])
    with pytest.raises(ValueError,
                       match=r"pool leaf '.*' of layer .* does not match"):
        cache_mod.adopt_pages(dst, rows, [0])


# ---------------------------------------------------------------------------
# Serving stack under kv_quant
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, prompts, max_new, **kw):
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    eng = ContinuousBatchingEngine(cfg, params, **kw)
    eng.run(reqs)
    return eng, {r.rid: list(r.tokens) for r in reqs}


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(2, cfg.vocab_size, n)]
            for n in lens]


def test_engine_int8_streams_match_fp32(llm):
    cfg, params = llm
    prompts = _prompts(cfg, (6, 11, 4))
    kw = dict(batch=2, max_len=32, paged=True, page_size=8, chunk_size=8)
    _, s_off = _run_engine(cfg, params, prompts, 8, **kw)
    eng, s_q = _run_engine(cfg, params, prompts, 8, kv_quant="int8", **kw)
    assert s_q == s_off
    assert eng.stats["completed"] == len(prompts)


def test_engine_rejects_quant_without_paged(llm):
    cfg, params = llm
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                 paged=False, kv_quant="int8")
    with pytest.raises(ValueError, match="kv_quant"):
        ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                 paged=True, kv_quant="int4")


def test_cow_prefix_sharing_with_int8_pools(llm):
    cfg, params = llm
    shared = _prompts(cfg, (13,), seed=2)[0]
    prompts = [list(shared) for _ in range(3)]
    kw = dict(batch=3, max_len=32, paged=True, page_size=8, chunk_size=8,
              kv_quant="int8")
    _, s_plain = _run_engine(cfg, params, prompts, 8, **kw)
    eng, s_cow = _run_engine(cfg, params, prompts, 8, prefix_sharing=True,
                             **kw)
    assert s_cow == s_plain
    assert eng.stats["shared_pages"] > 0
    assert eng.stats["completed"] == 3


def test_spec_decode_rollback_with_int8_pools(llm):
    cfg, params = llm
    motif = _prompts(cfg, (5,), seed=3)[0]
    prompts = [(motif * 4)[:18] for _ in range(2)]
    kw = dict(batch=2, max_len=64, paged=True, page_size=8, chunk_size=8,
              kv_quant="int8")
    _, s_off = _run_engine(cfg, params, prompts, 12, **kw)
    eng, s_spec = _run_engine(cfg, params, prompts, 12, spec_decode="ngram",
                              spec_k=4, **kw)
    assert s_spec == s_off
    assert eng.stats["accepted_tokens"] > 0


def test_replicated_server_with_int8_pools(llm):
    from repro.serving.replicated import MultiEngineServer

    cfg, params = llm
    prompts = _prompts(cfg, (9, 9, 6, 6), seed=4)
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=6)
            for i, p in enumerate(prompts)]
    server = MultiEngineServer(cfg, params, replicas=2, batch=2, max_len=32,
                               page_size=8, chunk_size=8, kv_quant="int8")
    for r in reqs:
        server.submit(r)
    while server.step():
        assert server.clock < 5_000
    server.sync()
    assert server.stats()["completed"] == len(reqs)
    assert server.converged()


# ---------------------------------------------------------------------------
# Tiered host-swap page memory
# ---------------------------------------------------------------------------

SWAP_KW = dict(batch=2, max_len=64, paged=True, page_size=8, num_pages=6,
               chunk_size=8, swap_min_tokens=16)


def test_swap_preemption_beats_recompute_and_streams_match(llm):
    cfg, params = llm
    prompts = _prompts(cfg, (24, 6), seed=5)
    eng0, s0 = _run_engine(cfg, params, prompts, 16, swap_tier_pages=0,
                           **SWAP_KW)
    eng1, s1 = _run_engine(cfg, params, prompts, 16, swap_tier_pages=8,
                           **SWAP_KW)
    assert eng0.stats["preempt_recompute"] > 0     # scenario really preempts
    assert eng1.stats["preempt_swap"] > 0
    assert eng1.stats["swap_outs"] > 0 and eng1.stats["swap_ins"] > 0
    assert s1 == s0                                # bit-identical streams
    assert eng1.stats["steps"] < eng0.stats["steps"]


def test_swap_tier_leaks_nothing_at_drain(llm):
    cfg, params = llm
    prompts = _prompts(cfg, (24, 6), seed=5)
    eng, _ = _run_engine(cfg, params, prompts, 16, swap_tier_pages=8,
                         **SWAP_KW)
    assert eng.stats["swap_ins"] == eng.stats["swap_outs"]
    assert sorted(eng._swap_free) == list(range(8))   # all slots returned
    assert eng.allocator.available == eng.allocator.num_pages


def test_swap_composes_with_int8_pools(llm):
    cfg, params = llm
    prompts = _prompts(cfg, (24, 6), seed=5)
    _, s_plain = _run_engine(cfg, params, prompts, 16, swap_tier_pages=0,
                             kv_quant="int8", **SWAP_KW)
    eng, s_swap = _run_engine(cfg, params, prompts, 16, swap_tier_pages=8,
                              kv_quant="int8", **SWAP_KW)
    assert s_swap == s_plain
    assert eng.stats["preempt_swap"] > 0


def test_swap_disabled_for_recurrent_state(llm):
    cfg, _ = llm
    cfg = cfg.replace(block_pattern=("attn", "rglru"), num_layers=4)
    params = _f32(lm.init(jax.random.PRNGKey(2), cfg))
    eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                   paged=True, page_size=8,
                                   swap_tier_pages=4)
    assert eng.swap_pool is None                   # recurrent rows recompute
