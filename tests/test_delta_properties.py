"""Property tests for delta-state sync (core/delta.py, merge.delta_merge).

Random op schedules across 2-5 clients check, for every registered CRDT:

  * fold-join permutation invariance (the join argument order never matters),
  * delta-sync ≡ full-state join, bit-for-bit,
  * idempotence of re-applied deltas,
  * overflow liveness: deltas truncated at capacity converge over later
    rounds instead of losing ops,
  * the ring-exchange collective (merge.delta_merge, run under vmap with an
    axis name) equals the host fold join on every replica.

Seeds are explicit pytest parameters so the schedules are random but
reproducible without the hypothesis package (conftest.py makes hypothesis
optional); each seed drives a fresh schedule, so the sweep is a bounded
property search.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import delta, doc, gset, lww, merge, rga, todo

SEEDS = range(8)


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# Random schedules: every replica applies its own ops; gossip via DeltaSync.
# ---------------------------------------------------------------------------


def _random_slotdoc_session(rng, n_clients: int, n_slots: int = 6,
                            cap: int = 32, rounds: int = 6):
    """Single-writer slots partitioned across clients; random appends."""
    base = doc.empty(n_slots, cap)
    replicas = [base for _ in range(n_clients)]
    for _ in range(rounds):
        who = int(rng.integers(0, n_clients))
        slot = int(rng.choice(np.arange(who, n_slots, n_clients)))
        n = int(rng.integers(1, 5))
        buf = np.zeros((4,), np.int32)
        buf[:n] = rng.integers(1, 99, size=n)
        replicas[who] = doc.append(replicas[who], slot, jnp.asarray(buf), n)
    return base, replicas


def _random_board_session(rng, n_clients: int, k: int = 8, rounds: int = 8):
    """Concurrent LWW writes: post/claim/complete with per-client clocks."""
    base = todo.empty(k)
    replicas = [base for _ in range(n_clients)]
    clocks = [1] * n_clients
    for _ in range(rounds):
        who = int(rng.integers(0, n_clients))
        key = int(rng.integers(0, k))
        b = replicas[who]
        op = rng.integers(0, 3)
        clk, cli = jnp.int32(clocks[who]), jnp.int32(who + 1)
        if op == 0:
            b = todo.post(b, key, jnp.zeros((k,), bool), clk, cli)
        elif op == 1:
            b = todo.claim(b, key, cli, clk, jnp.int32(0))
        else:
            b = todo.complete(b, key, cli, clk)
        clocks[who] += 1
        replicas[who] = b
    return base, replicas


def _random_glog_session(rng, n_clients: int, cap: int = 16, rounds: int = 10):
    base = gset.GLog.empty(n_clients, cap, {"x": ((), jnp.int32)})
    replicas = [base for _ in range(n_clients)]
    for _ in range(rounds):
        who = int(rng.integers(0, n_clients))
        replicas[who] = replicas[who].append(
            jnp.int32(who), x=jnp.int32(rng.integers(1, 99)))
    return base, replicas


def _random_rga_session(rng, n_clients: int, cap: int = 16, rounds: int = 8):
    base = rga.empty(n_clients + 1, cap)
    replicas = [base for _ in range(n_clients)]
    clocks = [1] * n_clients
    for _ in range(rounds):
        who = int(rng.integers(0, n_clients))
        state = replicas[who]
        toks, oids, n = rga.materialize(state)
        n = int(n)
        if n == 0 or rng.random() < 0.5:
            origin = state.head_oid
        else:
            origin = int(np.asarray(oids)[int(rng.integers(0, n))])
        run = int(rng.integers(1, 4))
        buf = np.zeros((4,), np.int32)
        buf[:run] = rng.integers(1, 99, size=run)
        replicas[who] = rga.insert_run(state, who + 1, clocks[who], origin,
                                       jnp.asarray(buf), run)
        clocks[who] += run
        if rng.random() < 0.25:
            oid = int(rng.integers(0, (n_clients + 1) * cap))
            replicas[who] = rga.delete(replicas[who], jnp.int32(oid))
    return base, replicas


SESSIONS = {
    "slotdoc": _random_slotdoc_session,
    "board": _random_board_session,
    "glog": _random_glog_session,
    "rga": _random_rga_session,
}


# ---------------------------------------------------------------------------
# fold-join permutation invariance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(SESSIONS))
@pytest.mark.parametrize("seed", SEEDS)
def test_fold_join_permutation_invariant(kind, seed):
    rng = np.random.default_rng(seed)
    n_clients = int(rng.integers(2, 6))
    _, replicas = SESSIONS[kind](rng, n_clients)
    m1 = merge.fold_join(replicas)
    perm = rng.permutation(n_clients)
    m2 = merge.fold_join([replicas[i] for i in perm])
    assert _trees_equal(m1, m2)


# ---------------------------------------------------------------------------
# delta sync ≡ full-state join, bit-for-bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(SESSIONS))
@pytest.mark.parametrize("seed", SEEDS)
def test_delta_sync_equals_fold_join(kind, seed):
    rng = np.random.default_rng(100 + seed)
    n_clients = int(rng.integers(2, 6))
    base, replicas = SESSIONS[kind](rng, n_clients)
    want = merge.fold_join(replicas)
    ds = delta.DeltaSync(base, capacity=32)
    outs = ds.sync(replicas)
    for out in outs:
        assert _trees_equal(out, want)
    assert ds.bytes_shipped >= 0


@pytest.mark.parametrize("seed", SEEDS)
def test_delta_sync_multi_round_with_interleaved_edits(seed):
    """Frontier threading across rounds: edits between syncs ship as O(Δ)."""
    rng = np.random.default_rng(200 + seed)
    n_clients = int(rng.integers(2, 6))
    base, replicas = _random_slotdoc_session(rng, n_clients)
    ds = delta.DeltaSync(base, capacity=32)
    for _ in range(3):
        replicas = ds.sync(replicas)
        assert all(_trees_equal(r, merge.fold_join(replicas))
                   for r in replicas)
        # Next burst of single-writer edits.
        for who in range(n_clients):
            slot = int(rng.choice(np.arange(who, 6, n_clients)))
            replicas[who] = doc.append(replicas[who], slot,
                                       jnp.asarray([7, 8, 0, 0]), 2)


# ---------------------------------------------------------------------------
# idempotence of re-applied deltas
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(SESSIONS))
@pytest.mark.parametrize("seed", SEEDS)
def test_delta_reapply_idempotent(kind, seed):
    rng = np.random.default_rng(300 + seed)
    n_clients = int(rng.integers(2, 6))
    base, replicas = SESSIONS[kind](rng, n_clients)
    fr = delta.frontier(base)
    for r in replicas:
        d, _ = delta.extract(r, fr, 32)
        once = delta.apply(base, d)
        twice = delta.apply(once, d)
        assert _trees_equal(once, twice)
        # Applying a replica's own delta back to itself is also a no-op.
        assert _trees_equal(r, delta.apply(r, d))


# ---------------------------------------------------------------------------
# overflow liveness: truncated deltas converge over later rounds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["slotdoc", "glog", "rga", "board"])
@pytest.mark.parametrize("seed", SEEDS)
def test_delta_overflow_converges_eventually(kind, seed):
    rng = np.random.default_rng(400 + seed)
    n_clients = int(rng.integers(2, 6))
    base, replicas = SESSIONS[kind](rng, n_clients, rounds=12)
    want = merge.fold_join(replicas)
    ds = delta.DeltaSync(base, capacity=2)     # far below the edit volume
    for _ in range(12):
        replicas = ds.sync(replicas)
        if all(_trees_equal(r, want) for r in replicas):
            break
    for r in replicas:
        assert _trees_equal(r, want)


# ---------------------------------------------------------------------------
# ring-exchange collective (merge.delta_merge under vmap)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(SESSIONS))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_delta_merge_ring_equals_fold_join(kind, seed):
    rng = np.random.default_rng(500 + seed)
    n_clients = int(rng.integers(2, 6))
    base, replicas = SESSIONS[kind](rng, n_clients)
    want = merge.fold_join(replicas)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *replicas)
    fr = delta.frontier(base)
    fr_stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_clients,) + x.shape), fr)

    def ring(state, f):
        return merge.delta_merge(state, f, "r", n_clients, capacity=32)

    merged, fr2 = jax.vmap(ring, axis_name="r")(stacked, fr_stacked)
    want_fr = delta.frontier(want)
    for i in range(n_clients):
        assert _trees_equal(jax.tree.map(lambda x: x[i], merged), want)
        # New frontier is identical everywhere and matches the merged state.
        assert _trees_equal(jax.tree.map(lambda x: x[i], fr2), want_fr)


def test_delta_merge_multi_axis_overflow_liveness():
    """Regression: a 2×2 grid where the second axis' forwarded delta
    overflows capacity must still converge on later rounds — the frontier is
    the pmin of what every replica observed, never ahead of an undelivered
    op (a join of per-axis shipped watermarks would lose regs 2,3 forever).
    """
    k = 8
    base = todo.empty(k)

    def writer(regs, client):
        b = base
        for r in regs:
            b = todo.post(b, r, jnp.zeros((k,), bool), jnp.int32(5),
                          jnp.int32(client))
        return b

    grid = [[writer([0, 1], 1), base], [writer([2, 3], 2), base]]
    want = merge.fold_join([grid[0][0], grid[1][0]])
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[jax.tree.map(lambda *ys: jnp.stack(ys), *row) for row in grid])
    fr = jax.tree.map(lambda x: jnp.broadcast_to(x, (2, 2) + x.shape),
                      delta.frontier(base))

    ring = jax.vmap(jax.vmap(
        lambda s, f: merge.delta_merge(s, f, ("a", "b"), (2, 2), capacity=2),
        axis_name="b"), axis_name="a")
    state = stacked
    for _ in range(4):
        state, fr = ring(state, fr)
        if all(_trees_equal(jax.tree.map(lambda x: x[i, j], state), want)
               for i in range(2) for j in range(2)):
            break
    for i in range(2):
        for j in range(2):
            assert _trees_equal(jax.tree.map(lambda x: x[i, j], state), want)


@pytest.mark.parametrize("seed", [0, 1])
def test_delta_merge_dict_container_ring(seed):
    """The fused serving step's coord dict shape syncs through the ring."""
    rng = np.random.default_rng(600 + seed)
    n = 4
    base = {"doc": doc.empty(4, 16), "heartbeats": gset.GCounter.zeros(n)}
    replicas = []
    for i in range(n):
        d = doc.append(base["doc"], i, jnp.asarray([i + 1, i + 2, 0, 0]), 2)
        hb = gset.GCounter(jnp.zeros((n,), jnp.int32).at[i].set(
            int(rng.integers(1, 9))))
        replicas.append({"doc": d, "heartbeats": hb})
    want = merge.fold_join(replicas)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *replicas)
    fr = delta.frontier(base)
    fr_stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n,) + x.shape), fr)
    merged, _ = jax.vmap(
        lambda s, f: merge.delta_merge(s, f, "r", n, capacity=8),
        axis_name="r")(stacked, fr_stacked)
    for i in range(n):
        assert _trees_equal(jax.tree.map(lambda x: x[i], merged), want)


# ---------------------------------------------------------------------------
# wire-cost acceptance: delta < pmax at low edit rates
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rate", [0.01, 0.05])
def test_delta_bytes_beat_pmax_at_low_edit_rates(rate):
    from benchmarks.bench_merge import sweep_cell
    cell = sweep_cell(4, 256, rate, runs=1)
    assert cell["delta_exact"]
    assert cell["bytes"]["delta"] < cell["bytes"]["pmax"], cell["bytes"]
    assert cell["bytes"]["pmax"] < cell["bytes"]["allgather"]


def test_lww_delta_no_starvation_under_churn():
    """Regression: sustained churn of >= capacity registers must not starve
    another register's pending write — extraction ships oldest keys first,
    and a starved key is by definition the oldest changed one."""
    k = 16
    bank = lww.empty(k, {"v": ((), jnp.int32)})
    peer = lww.empty(k, {"v": ((), jnp.int32)})
    fr = delta.frontier(peer)
    bank = lww.write(bank, jnp.int32(5), jnp.int32(1), jnp.int32(2),
                     v=jnp.int32(55))
    clock = 2
    for _ in range(4):
        for r in range(4):                   # churn registers 0-3 each round
            bank = lww.write(bank, jnp.int32(r), jnp.int32(clock),
                             jnp.int32(1), v=jnp.int32(clock))
            clock += 1
        d, fr = delta.extract(bank, fr, 4)
        peer = delta.apply(peer, d)
        if int(peer.clock[5]) > 0:
            break
    assert int(peer.payload["v"][5]) == 55, "register 5 starved by churn"


def test_lww_delta_capacity_smaller_than_bank():
    """Extraction left-packs changed registers; unshipped ones keep their
    place in the frontier diff and ship next round."""
    k = 16
    bank = lww.empty(k, {"v": ((), jnp.int32)})
    for i in range(6):
        bank = lww.write(bank, jnp.int32(i), jnp.int32(i + 1),
                         jnp.int32(1), v=jnp.int32(10 * i))
    fr = delta.frontier(lww.empty(k, {"v": ((), jnp.int32)}))
    d1, fr1 = delta.extract(bank, fr, 4)
    assert int(np.sum(np.asarray(d1.idx) >= 0)) == 4
    d2, fr2 = delta.extract(bank, fr1, 4)
    assert int(np.sum(np.asarray(d2.idx) >= 0)) == 2
    empty_bank = lww.empty(k, {"v": ((), jnp.int32)})
    got = delta.apply(delta.apply(empty_bank, d1), d2)
    assert _trees_equal(got, bank)
