"""End-to-end behaviour tests for the paper's system claims.

The paper's three research questions, as executable assertions on the real
stack (tiny decoder, full coordination machinery):
  RQ1 structure — parallel speedup on decoupled tasks (decode-step units);
  RQ2 objective part — volume inflation appears only in parallel mode;
  RQ3 — strong eventual consistency: replicas converge bit-identically,
        zero character-level merge failures, at-most-one-winner claims.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.agents.orchestrator import count_conflicts, make_sim_llm, run_task
from repro.agents.tasks import TASKS
from repro.core import doc as doc_mod
from repro.core import merge, rga


@pytest.fixture(scope="module")
def llm():
    return make_sim_llm()


def test_rq1_decoupled_speedup_and_coupled_structure(llm):
    cfg, params = llm
    res = {}
    for task in ("tic_tac_toe", "visualizer"):
        seq = run_task(cfg, params, TASKS[task], mode="sequential", seed=0)
        par = run_task(cfg, params, TASKS[task], mode="parallel",
                       n_agents=4, seed=0)
        res[task] = (seq, par)
    # Decoupled: parallel strictly faster (raw).
    s, p = res["tic_tac_toe"]
    assert p.steps < s.steps
    # Coupled + inflated: raw advantage shrinks or inverts...
    s2, p2 = res["visualizer"]
    decoupled_gain = p.steps / s.steps
    coupled_gain = p2.steps / s2.steps
    assert coupled_gain > decoupled_gain
    # ...but normalized (per-token) time still favors parallel (paper B.1).
    assert p2.steps_per_1k_tokens < s2.steps_per_1k_tokens


def test_rq2_volume_inflation_only_in_parallel(llm):
    cfg, params = llm
    seq = run_task(cfg, params, TASKS["dashboard"], mode="sequential", seed=1)
    par = run_task(cfg, params, TASKS["dashboard"], mode="parallel",
                   n_agents=4, seed=1)
    assert par.gen_tokens > 1.5 * seq.gen_tokens


def test_rq3_full_suite_convergence(llm):
    cfg, params = llm
    for task in TASKS:
        r = run_task(cfg, params, TASKS[task], mode="parallel", n_agents=3,
                     seed=2)
        assert r.converged, f"{task}: replicas diverged"


def test_rq3_zero_character_level_loss():
    """Concurrent RGA edits: all inserted tokens survive, exactly once."""
    s = rga.empty(4, 64)
    replicas = [s, s, s]
    total = 0
    for i, tok0 in enumerate((10, 20, 30)):
        run = jnp.asarray([tok0, tok0 + 1, tok0 + 2, 0])
        replicas[i] = rga.insert_run(replicas[i], i + 1, 5 + i,
                                     s.head_oid, run, 3)
        total += 3
    m = merge.fold_join(replicas)
    toks, _, n = rga.materialize(m)
    assert int(n) == total
    assert sorted(np.asarray(toks[:total]).tolist()) == sorted(
        [10, 11, 12, 20, 21, 22, 30, 31, 32])


def test_semantic_conflicts_detectable_despite_convergence():
    """The paper's key distinction: character-level convergence does NOT
    imply semantic consistency — duplicate declarations survive the merge."""
    d = doc_mod.empty(2, 16)
    decl = 5              # token = 5 (mod 13 == 5) declares symbol 5
    d = doc_mod.append(d, 0, jnp.asarray([decl, 1, 0, 0]), 2)
    d = doc_mod.append(d, 1, jnp.asarray([decl, 2, 0, 0]), 2)
    conflicts, total = count_conflicts(d)
    assert conflicts == 1 and total == 2
