"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device; only launch/dryrun.py
forces 512 placeholder devices (and only when run as its own process).

``hypothesis`` is optional: property modules that need it call
``pytest.importorskip("hypothesis")`` at import time and skip cleanly when it
is absent (tests/test_delta_properties.py runs its property sweeps off
explicit seed parameters instead, so delta coverage survives either way).
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

try:
    import hypothesis
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="module", autouse=True)
def _bounded_compile_cache():
    # XLA's CPU backend keeps every compiled executable's JIT'd code alive
    # for the life of the process; past several hundred distinct compiles
    # the ORC JIT can segfault inside backend_compile (observed when the
    # whole suite runs single-process under ``pytest -x``).  Dropping the
    # trace/compile caches at module boundaries frees each module's
    # executables once its fixtures die, bounding resident JIT state at
    # the cost of a handful of recompiles per module.
    yield
    jax.clear_caches()


def pytest_configure(config):
    if not HAVE_HYPOTHESIS:
        return
    # Keep hypothesis deadlines off: first call pays jit compile time.
    from hypothesis import settings, HealthCheck
    settings.register_profile(
        "repro",
        deadline=None,
        max_examples=25,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    # "ci" = the repro profile with a fixed derivation seed, so the CI
    # property job is reproducible run-to-run (HYPOTHESIS_PROFILE=ci).
    settings.register_profile(
        "ci", settings.get_profile("repro"), derandomize=True)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "repro"))
