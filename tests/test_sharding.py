"""Partitioner unit tests: spec rules, divisibility guards, FSDP, caches."""
from __future__ import annotations

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as configs
from repro.models import lm
from repro.sharding.partition import Partitioner

# Specs are pure metadata — a tiny mesh with the production axis names is
# enough to unit-test the rules (sizes chosen to exercise divisibility).
pytestmark = pytest.mark.usefixtures()


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Mesh stand-in with arbitrary axis sizes (specs never touch devices)."""
    def __init__(self, data=16, model=16):
        self.shape = {"data": data, "model": model}
        self.axis_names = ("data", "model")


def test_attention_param_rules():
    part = Partitioner(FakeMesh(), fsdp=False)
    assert part.param_spec(("blk", "attn", "wq", "w"), (1024, 2048)) == \
        P(None, "model")
    assert part.param_spec(("blk", "attn", "wo", "w"), (2048, 1024)) == \
        P("model", None)
    # Stacked (scan) params get a leading None.
    assert part.param_spec(("groups", "0", "attn", "wq", "w"),
                           (8, 1024, 2048)) == P(None, None, "model")


def test_divisibility_guard_replicates():
    part = Partitioner(FakeMesh(model=16), fsdp=False)
    # MQA kv projection with 1 head * 128 dims = 128 columns: divisible.
    assert part.param_spec(("a", "wk", "w"), (1024, 128)) == P(None, "model")
    # Odd vocab (whisper): embed rows not divisible -> replicated.
    assert part.param_spec(("embed", "w"), (51865, 384)) == P(None, None)


def test_fsdp_adds_data_axis():
    part = Partitioner(FakeMesh(data=16, model=16), fsdp=True)
    spec = part.param_spec(("a", "ffn", "up", "w"), (4096, 16384))
    assert spec == P(("data",), "model")
    # 1-D params are never FSDP-sharded.
    assert part.param_spec(("norm", "scale"), (4096,)) == P(None)


def test_moe_expert_parallel():
    part = Partitioner(FakeMesh(), fsdp=False)
    assert part.param_spec(("ffn", "experts", "gate"), (64, 2048, 1408)) == \
        P("model", None, None)


def test_cache_specs_head_vs_seq():
    part = Partitioner(FakeMesh(model=16), fsdp=False)
    # 16 kv heads: shard heads.
    s = part.cache_entry_spec(("groups", "0", "k"), (8, 128, 16, 32768, 128),
                              shard_batch=True, stacked=True)
    assert s == P(None, ("data",), "model", None, None)
    # 8 kv heads (not divisible): shard sequence instead.
    s = part.cache_entry_spec(("k",), (128, 8, 32768, 128),
                              shard_batch=True, stacked=False)
    assert s == P(("data",), None, "model", None)


def test_mla_cache_replication_variant():
    base = Partitioner(FakeMesh(), fsdp=False)
    repl = Partitioner(FakeMesh(), fsdp=False, mla_cache="replicated")
    shape = (128, 32768, 512)
    assert base.cache_entry_spec(("ckv",), shape, shard_batch=True,
                                 stacked=False) == P(("data",), None, "model")
    assert repl.cache_entry_spec(("ckv",), shape, shard_batch=True,
                                 stacked=False) == P(("data",), None, None)
    seq = Partitioner(FakeMesh(), fsdp=False, mla_cache="seq")
    assert seq.cache_entry_spec(("ckv",), shape, shard_batch=True,
                                stacked=False) == P(("data",), "model", None)


def test_every_arch_param_tree_gets_specs():
    """No param path falls through the rules with a wrong-rank spec."""
    part = Partitioner(FakeMesh(), fsdp=True)
    for name in configs.ARCHS:
        cfg = configs.reduced(configs.get(name))
        abs_p = lm.abstract_params(cfg)
        specs = part.params_specs(abs_p)
        for leaf, spec in zip(jax.tree.leaves(abs_p), jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P))):
            assert len(spec) <= len(leaf.shape)
