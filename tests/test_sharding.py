"""Partitioner unit tests: spec rules, divisibility guards, FSDP, caches."""
from __future__ import annotations

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as configs
from repro.models import lm
from repro.sharding.partition import Partitioner

# Specs are pure metadata — a tiny mesh with the production axis names is
# enough to unit-test the rules (sizes chosen to exercise divisibility).
pytestmark = pytest.mark.usefixtures()


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Mesh stand-in with arbitrary axis sizes (specs never touch devices)."""
    def __init__(self, data=16, model=16):
        self.shape = {"data": data, "model": model}
        self.axis_names = ("data", "model")


def test_attention_param_rules():
    part = Partitioner(FakeMesh(), fsdp=False)
    assert part.param_spec(("blk", "attn", "wq", "w"), (1024, 2048)) == \
        P(None, "model")
    assert part.param_spec(("blk", "attn", "wo", "w"), (2048, 1024)) == \
        P("model", None)
    # Stacked (scan) params get a leading None.
    assert part.param_spec(("groups", "0", "attn", "wq", "w"),
                           (8, 1024, 2048)) == P(None, None, "model")


def test_divisibility_guard_replicates():
    part = Partitioner(FakeMesh(model=16), fsdp=False)
    # MQA kv projection with 1 head * 128 dims = 128 columns: divisible.
    assert part.param_spec(("a", "wk", "w"), (1024, 128)) == P(None, "model")
    # Odd vocab (whisper): embed rows not divisible -> replicated.
    assert part.param_spec(("embed", "w"), (51865, 384)) == P(None, None)


def test_fsdp_adds_data_axis():
    part = Partitioner(FakeMesh(data=16, model=16), fsdp=True)
    spec = part.param_spec(("a", "ffn", "up", "w"), (4096, 16384))
    assert spec == P(("data",), "model")
    # 1-D params are never FSDP-sharded.
    assert part.param_spec(("norm", "scale"), (4096,)) == P(None)


def test_moe_expert_parallel():
    part = Partitioner(FakeMesh(), fsdp=False)
    assert part.param_spec(("ffn", "experts", "gate"), (64, 2048, 1408)) == \
        P("model", None, None)


def test_cache_specs_head_vs_seq():
    part = Partitioner(FakeMesh(model=16), fsdp=False)
    # 16 kv heads: shard heads.
    s = part.cache_entry_spec(("groups", "0", "k"), (8, 128, 16, 32768, 128),
                              shard_batch=True, stacked=True)
    assert s == P(None, ("data",), "model", None, None)
    # 8 kv heads (not divisible): shard sequence instead.
    s = part.cache_entry_spec(("k",), (128, 8, 32768, 128),
                              shard_batch=True, stacked=False)
    assert s == P(("data",), None, "model", None)


def test_mla_cache_replication_variant():
    base = Partitioner(FakeMesh(), fsdp=False)
    repl = Partitioner(FakeMesh(), fsdp=False, mla_cache="replicated")
    shape = (128, 32768, 512)
    assert base.cache_entry_spec(("ckv",), shape, shard_batch=True,
                                 stacked=False) == P(("data",), None, "model")
    assert repl.cache_entry_spec(("ckv",), shape, shard_batch=True,
                                 stacked=False) == P(("data",), None, None)
    seq = Partitioner(FakeMesh(), fsdp=False, mla_cache="seq")
    assert seq.cache_entry_spec(("ckv",), shape, shard_batch=True,
                                stacked=False) == P(("data",), "model", None)


def test_every_arch_param_tree_gets_specs():
    """No param path falls through the rules with a wrong-rank spec."""
    part = Partitioner(FakeMesh(), fsdp=True)
    for name in configs.ARCHS:
        cfg = configs.reduced(configs.get(name))
        abs_p = lm.abstract_params(cfg)
        specs = part.params_specs(abs_p)
        for leaf, spec in zip(jax.tree.leaves(abs_p), jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P))):
            assert len(spec) <= len(leaf.shape)


def test_paged_pool_leaf_rules():
    """Pools shard over heads (MHA) / latent features (MLA); the page dim is
    shared across rows (never batch-sharded); block tables replicate."""
    part = Partitioner(FakeMesh(model=16), fsdp=False)
    # MHA pool [P, Hkv, ps, D], 16 heads: heads on model, page dim whole.
    s = part.cache_entry_spec(("groups", "0", "k_pages"),
                              (8, 4096, 16, 64, 128),
                              shard_batch=True, stacked=True)
    assert s == P(None, None, "model", None, None)
    # Non-divisible heads replicate (no fallback onto the page dim).
    s = part.cache_entry_spec(("v_pages",), (4096, 6, 64, 128),
                              shard_batch=True, stacked=False)
    assert s == P(None, None, None, None)
    # MLA latent pool [P, ps, Dp]: latent-feature axis on model.
    s = part.cache_entry_spec(("latent_pages",), (4096, 64, 640),
                              shard_batch=True, stacked=False)
    assert s == P(None, None, "model")
    # Block tables replicate everywhere.
    s = part.cache_entry_spec(("groups", "0", "block_tables"), (8, 128, 64),
                              shard_batch=True, stacked=True)
    assert s == P(None, None, None)


def test_paged_cache_tree_gets_specs():
    """The full paged cache tree (MHA and MLA archs) maps through the
    partitioner with correct ranks."""
    part = Partitioner(FakeMesh(), fsdp=False)
    for arch in ("olmo-1b", "deepseek-v2-lite-16b"):
        cfg = configs.reduced(configs.get(arch))
        cache = jax.eval_shape(
            lambda c=cfg: lm.init_cache(c, 8, 256, paged=True, page_size=64))
        specs = jax.tree_util.tree_map_with_path(
            lambda path, leaf: part.cache_entry_spec(
                tuple(getattr(k, "key", getattr(k, "name", k))
                      for k in path),
                np.shape(leaf), shard_batch=True,
                stacked="groups" in str(path)),
            cache)
        for leaf, spec in zip(jax.tree.leaves(cache),
                              jax.tree.leaves(
                                  specs,
                                  is_leaf=lambda x: isinstance(x, P))):
            assert len(spec) <= len(leaf.shape)


def test_paged_decode_lowers_multi_device():
    """The fused paged step (MHA pools + MLA latent pools) lowers and
    compiles on a multi-device mesh with the pool sharding rules — spawned
    with 8 host devices so the main process keeps its single-device view."""
    import subprocess
    import sys
    import textwrap
    from pathlib import Path

    src = str(Path(__file__).resolve().parent.parent / "src")
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.configs as configs
        from repro.models import lm
        from repro.serving import engine as engine_mod
        from repro.sharding.partition import Partitioner
        from repro.sharding import activation

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch in ("olmo-1b", "deepseek-v2-lite-16b"):
            cfg = configs.reduced(configs.get(arch))
            part = Partitioner(mesh, fsdp=False)
            p_abs = lm.abstract_params(cfg)
            p_shard = part.params_shardings(p_abs)
            b = 8
            cache_abs = jax.eval_shape(
                lambda c=cfg: lm.init_cache(c, b, 256, paged=True,
                                            page_size=64))
            c_shard = part.cache_shardings(cache_abs, shard_batch=True)
            bspec = NamedSharding(mesh, P(("data",)))
            sd = jax.ShapeDtypeStruct
            binding = activation.standard_binding(("data",),
                                                  seq_parallel=True)
            with activation.bind(binding):
                jitted = jax.jit(
                    engine_mod.make_serve_step(cfg),
                    in_shardings=(p_shard, c_shard, bspec, bspec,
                                  NamedSharding(mesh, P(None))),
                    donate_argnums=(1,))
                with mesh:
                    jitted.lower(p_abs, cache_abs, sd((b,), jnp.int32),
                                 sd((b,), jnp.int32),
                                 sd((2,), jnp.uint32)).compile()
            print(arch, "OK")
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         env={"PYTHONPATH": src, "PATH": "/usr/bin:/bin",
                              "HOME": "/root"},
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "olmo-1b OK" in out.stdout
    assert "deepseek-v2-lite-16b OK" in out.stdout
