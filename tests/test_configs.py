"""Config registry: exact assigned hyperparameters + shape applicability."""
from __future__ import annotations

import pytest

import repro.configs as configs
from repro.configs.shapes import SHAPES, applicable

ASSIGNED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    "granite-34b": (88, 6144, 48, 1, 24576, 49152),
    "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
    "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
    "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
    "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
}


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_exact_assigned_config(name):
    c = configs.get(name)
    want = ASSIGNED[name]
    got = (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
           c.vocab_size)
    assert got == want, f"{name}: {got} != {want}"


def test_moe_configs():
    for name in ("deepseek-moe-16b", "deepseek-v2-lite-16b"):
        m = configs.get(name).moe
        assert (m.num_experts, m.top_k, m.num_shared) == (64, 6, 2)
    assert configs.get("deepseek-v2-lite-16b").mla.kv_lora_rank == 512


def test_frontend_stubs():
    assert configs.get("paligemma-3b").num_prefix_tokens == 256
    enc = configs.get("whisper-tiny").encoder
    assert enc is not None and enc.seq_len == 1500


def test_recurrentgemma_pattern():
    c = configs.get("recurrentgemma-2b")
    assert c.block_pattern == ("rglru", "rglru", "local")
    assert c.window == 2048
    assert c.tail_blocks == ("rglru", "rglru")      # 26 = 8*3 + 2


def test_applicability_matrix():
    long = SHAPES["long_500k"]
    runs = {n for n in configs.ARCHS
            if applicable(configs.get(n), long)[0]}
    assert runs == {"xlstm-125m", "recurrentgemma-2b"}
    # Every arch runs every other shape.
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        for n in configs.ARCHS:
            ok, _ = applicable(configs.get(n), SHAPES[shape])
            assert ok


def test_reduced_preserves_family():
    for n in configs.ARCHS:
        full = configs.get(n)
        red = configs.reduced(full)
        assert red.block_pattern == full.block_pattern
        assert (red.moe is None) == (full.moe is None)
        assert (red.mla is None) == (full.mla is None)
        assert (red.encoder is None) == (full.encoder is None)
        assert red.param_count() < full.param_count() / 50
