"""Collective CRDT merges on a real multi-device mesh.

Spawned as a subprocess with 8 host devices (the main pytest process must
keep the single-device view for everything else).  Verifies that the
all-gather and pmax merge strategies both produce the exact join across
divergent per-device replicas — the "collectives are the relay" claim —
and that the fused serve step lowers on the debug mesh.
"""
from __future__ import annotations

import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import doc as doc_mod, gset, lww, merge, todo
    from repro.serving import engine as engine_mod

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    R = 4

    # --- divergent per-replica LWW boards: each data shard wrote its own key
    K = 8
    def make_replica(i):
        b = todo.empty(K)
        b = todo.post(b, i, jnp.zeros((K,), bool), jnp.int32(10 + i),
                      jnp.int32(i + 1))
        b = todo.claim(b, i, jnp.int32(i + 1), jnp.int32(20 + i), jnp.int32(0))
        return b
    replicas = [make_replica(i) for i in range(R)]
    expected = merge.fold_join(replicas)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *replicas)

    for strategy in ("pmax", "allgather"):
        def local(st):
            s = jax.tree.map(lambda x: jnp.squeeze(x, 0), st)
            m = merge.collective_merge(s, "data", strategy)
            return jax.tree.map(lambda x: x[None], m)
        specs = jax.tree.map(lambda x: P("data", *([None] * (x.ndim - 1))),
                             stacked)
        out = jax.jit(merge.shard_map(local, mesh=mesh, in_specs=(specs,),
                                      out_specs=specs,
                                      check_vma=False))(stacked)
        for i in range(R):
            got = jax.tree.map(lambda x: np.asarray(x[i]), out)
            want = jax.tree.map(np.asarray, expected)
            for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
                np.testing.assert_array_equal(g, w)
        print(f"{strategy}: exact join on all replicas OK")

    # --- delta-state ring sync: O(Δ) ppermute exchange on the real mesh -----
    from repro.core import delta as delta_mod
    R_docs = []
    base_doc = doc_mod.empty(4, 16)
    for i in range(R):
        R_docs.append(doc_mod.append(base_doc, i,
                                     jnp.asarray([i + 1, i + 2, 0, 0]), 2))
    expected_delta = merge.fold_join(R_docs)
    stacked_docs = jax.tree.map(lambda *xs: jnp.stack(xs), *R_docs)
    fr0 = delta_mod.frontier(base_doc)
    fr_stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (R,) + x.shape),
                              fr0)

    def local_delta(st, fr):
        s = jax.tree.map(lambda x: jnp.squeeze(x, 0), st)
        f = jax.tree.map(lambda x: jnp.squeeze(x, 0), fr)
        m, f2 = merge.delta_merge(s, f, ("data",), (R,), capacity=8)
        return (jax.tree.map(lambda x: x[None], m),
                jax.tree.map(lambda x: x[None], f2))

    d_specs = jax.tree.map(lambda x: P("data", *([None] * (x.ndim - 1))),
                           stacked_docs)
    f_specs = jax.tree.map(lambda x: P("data", *([None] * (x.ndim - 1))),
                           fr_stacked)
    out_docs, out_fr = jax.jit(merge.shard_map(
        local_delta, mesh=mesh, in_specs=(d_specs, f_specs),
        out_specs=(d_specs, f_specs), check_vma=False))(stacked_docs,
                                                        fr_stacked)
    want_fr = delta_mod.frontier(expected_delta)
    for i in range(R):
        got = jax.tree.map(lambda x: np.asarray(x[i]), out_docs)
        for g, w in zip(jax.tree.leaves(got),
                        jax.tree.leaves(jax.tree.map(np.asarray,
                                                     expected_delta))):
            np.testing.assert_array_equal(g, w)
        np.testing.assert_array_equal(np.asarray(out_fr.length[i]),
                                      np.asarray(want_fr.length))
    print("delta: exact join on all replicas OK")

    # --- SlotDoc + heartbeat merge through the fused-serve-step helper
    docs = []
    for i in range(R):
        d = doc_mod.empty(4, 16)
        d = doc_mod.append(d, i, jnp.asarray([i + 1, i + 2, 0, 0]), 2)
        docs.append({"doc": d, "heartbeats": gset.GCounter(
            jnp.zeros((R,), jnp.int32).at[i].set(5))})
    expected_doc = merge.fold_join([x["doc"] for x in docs])
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *docs)
    merge_fn = engine_mod.make_coord_merge(mesh, ("data",), "pmax")
    out = jax.jit(merge_fn)(stacked)
    for i in range(R):
        got = jax.tree.map(lambda x: np.asarray(x[i]), out["doc"])
        for g, w in zip(jax.tree.leaves(got),
                        jax.tree.leaves(jax.tree.map(np.asarray, expected_doc))):
            np.testing.assert_array_equal(g, w)
    hb = np.asarray(out["heartbeats"].counts[0])
    np.testing.assert_array_equal(hb, np.full((R,), 5))
    print("fused coord merge OK")

    # --- the fused decode+coordination step EXECUTES on the mesh -----------
    import repro.configs as configs
    from repro.models import lm as lm_mod
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=64)
    params = lm_mod.init(jax.random.PRNGKey(0), cfg)
    B = 8                                   # 2 agent rows per data shard
    cache = lm_mod.init_cache(cfg, B, 16)
    coord = {"doc": doc_mod.empty(8, 16),
             "heartbeats": gset.GCounter.zeros(R)}
    coord = engine_mod.replicate_coord(coord, R)
    step = engine_mod.make_fused_serve_step(cfg, mesh, ("data",))
    token = jnp.arange(2, 2 + B, dtype=jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    slots = jnp.arange(B, dtype=jnp.int32) % 8
    active = jnp.ones((B,), bool)
    with mesh:
        for t in range(3):
            token, cache, pos, coord = step(params, cache, token, pos,
                                            slots, active, coord,
                                            jnp.int32(t))
    lengths = np.asarray(coord["doc"].length)
    # All replicas observed all agents' appends (3 tokens per slot).
    for i in range(R):
        np.testing.assert_array_equal(lengths[i], np.full((8,), 3))
    digests = [int(doc_mod.digest(jax.tree.map(lambda x: x[i],
                                               coord["doc"])))
               for i in range(R)]
    assert len(set(digests)) == 1, digests
    print("fused serve step convergence OK")

    # --- the fused step with DELTA coordination also converges -------------
    coord2 = {"doc": doc_mod.empty(8, 16),
              "heartbeats": gset.GCounter.zeros(R)}
    coord2 = engine_mod.replicate_coord(
        engine_mod.with_delta_frontier(coord2), R)
    cache2 = lm_mod.init_cache(cfg, B, 16)
    step2 = engine_mod.make_fused_serve_step(cfg, mesh, ("data",),
                                             merge_strategy="delta",
                                             delta_capacity=8)
    token2 = jnp.arange(2, 2 + B, dtype=jnp.int32)
    pos2 = jnp.zeros((B,), jnp.int32)
    with mesh:
        for t in range(3):
            token2, cache2, pos2, coord2 = step2(params, cache2, token2,
                                                 pos2, slots, active,
                                                 coord2, jnp.int32(t))
    lengths2 = np.asarray(coord2["doc"].length)
    for i in range(R):
        np.testing.assert_array_equal(lengths2[i], np.full((8,), 3))
    digests2 = [int(doc_mod.digest(jax.tree.map(lambda x: x[i],
                                                coord2["doc"])))
                for i in range(R)]
    assert len(set(digests2)) == 1, digests2
    # Frontier tracked every appended token (merge_every=1, no overflow).
    np.testing.assert_array_equal(
        np.asarray(coord2["frontier"]["doc"].length[0]), np.full((8,), 3))
    print("fused delta serve step convergence OK")
    print("ALL_OK")
""")


def test_collective_merges_on_8_devices():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ALL_OK" in proc.stdout
    assert "pmax: exact join" in proc.stdout
    assert "allgather: exact join" in proc.stdout
    assert "delta: exact join" in proc.stdout
    assert "fused delta serve step convergence OK" in proc.stdout
