"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs ref.py.

Every kernel is validated against its pure-jnp oracle across uneven shapes
(exercising the padding paths), GQA group factors, dtypes, and block sizes.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# lww_merge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,d", [(1, 1), (7, 3), (128, 8), (1000, 17), (4096, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_lww_merge_sweep(k, d, dtype):
    ka = jnp.asarray(RNG.integers(0, 10_000, k), jnp.int32)
    kb = jnp.asarray(RNG.integers(0, 10_000, k), jnp.int32)
    if dtype == jnp.int32:
        pa = jnp.asarray(RNG.integers(-99, 99, (k, d)), dtype)
        pb = jnp.asarray(RNG.integers(-99, 99, (k, d)), dtype)
    else:
        pa = jnp.asarray(RNG.normal(size=(k, d)), dtype)
        pb = jnp.asarray(RNG.normal(size=(k, d)), dtype)
    k1, p1 = ops.lww_merge(ka, pa, kb, pb)
    k2, p2 = ref.lww_merge(ka, pa, kb, pb)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_lww_merge_is_join():
    """Kernel output == semilattice join == commuted kernel output."""
    k = 513
    ka = jnp.asarray(RNG.integers(0, 100, k), jnp.int32)
    kb = jnp.asarray(RNG.integers(0, 100, k), jnp.int32)
    pa = jnp.asarray(RNG.normal(size=(k, 5)), jnp.float32)
    pb = jnp.asarray(RNG.normal(size=(k, 5)), jnp.float32)
    k1, p1 = ops.lww_merge(ka, pa, kb, pb)
    k2, p2 = ops.lww_merge(kb, pb, ka, pa)
    # Commutative where keys differ; ties keep either payload — keys unique
    # in protocol use, so require equality only where keys differ.
    diff = np.asarray(ka) != np.asarray(kb)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    np.testing.assert_array_equal(np.asarray(p1)[diff], np.asarray(p2)[diff])


# ---------------------------------------------------------------------------
# delta_apply (delta-state sync scatter kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,d,dc", [(1, 1, 1), (7, 3, 4), (128, 8, 16),
                                    (1000, 17, 33), (4096, 4, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_delta_apply_sweep(k, d, dc, dtype):
    dc = min(dc, k)
    key = jnp.asarray(RNG.integers(0, 10_000, k), jnp.int32)
    if dtype == jnp.int32:
        pay = jnp.asarray(RNG.integers(-99, 99, (k, d)), dtype)
        dpay = jnp.asarray(RNG.integers(-99, 99, (dc, d)), dtype)
    else:
        pay = jnp.asarray(RNG.normal(size=(k, d)), dtype)
        dpay = jnp.asarray(RNG.normal(size=(dc, d)), dtype)
    idx = RNG.permutation(k)[:dc].astype(np.int32)   # unique targets
    empty = RNG.random(dc) < 0.25                    # some empty lanes
    d_idx = jnp.asarray(np.where(empty, -1, idx), jnp.int32)
    d_key = jnp.asarray(RNG.integers(0, 20_000, dc), jnp.int32)
    k1, p1 = ops.delta_apply(key, pay, d_idx, d_key, dpay)
    k2, p2 = ref.delta_apply(key, pay, d_idx, d_key, dpay)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_delta_apply_matches_semantic_lww_writes():
    """Kernel result == applying each winning delta lane as an LWW write."""
    k, d, dc = 64, 3, 16
    key = np.asarray(RNG.integers(0, 100, k), np.int32)
    pay = np.asarray(RNG.integers(-9, 9, (k, d)), np.int32)
    idx = RNG.permutation(k)[:dc].astype(np.int32)
    dkey = np.asarray(RNG.integers(0, 200, dc), np.int32)
    dpay = np.asarray(RNG.integers(-9, 9, (dc, d)), np.int32)
    want_key, want_pay = key.copy(), pay.copy()
    for j in range(dc):
        if dkey[j] > want_key[idx[j]]:
            want_key[idx[j]] = dkey[j]
            want_pay[idx[j]] = dpay[j]
    k1, p1 = ops.delta_apply(jnp.asarray(key), jnp.asarray(pay),
                             jnp.asarray(idx), jnp.asarray(dkey),
                             jnp.asarray(dpay))
    np.testing.assert_array_equal(np.asarray(k1), want_key)
    np.testing.assert_array_equal(np.asarray(p1), want_pay)


def test_delta_apply_idempotent_and_empty():
    k, d, dc = 100, 5, 8
    key = jnp.asarray(RNG.integers(0, 100, k), jnp.int32)
    pay = jnp.asarray(RNG.integers(-9, 9, (k, d)), jnp.int32)
    # All-empty delta: no-op.
    k0, p0 = ops.delta_apply(key, pay, jnp.full((dc,), -1, jnp.int32),
                             jnp.zeros((dc,), jnp.int32),
                             jnp.zeros((dc, d), jnp.int32))
    np.testing.assert_array_equal(np.asarray(k0), np.asarray(key))
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(pay))
    # Re-applying a delta is a no-op (keys no longer beat the bank).
    idx = jnp.asarray(RNG.permutation(k)[:dc], jnp.int32)
    dkey = jnp.asarray(RNG.integers(100, 200, dc), jnp.int32)
    dpay = jnp.asarray(RNG.integers(-9, 9, (dc, d)), jnp.int32)
    k1, p1 = ops.delta_apply(key, pay, idx, dkey, dpay)
    k2, p2 = ops.delta_apply(k1, p1, idx, dkey, dpay)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, Hq, Hkv, Tq, Tk, D, causal, window)
    (1, 1, 1, 128, 128, 64, True, None),
    (2, 4, 2, 96, 96, 32, True, None),          # uneven T -> padding path
    (1, 8, 1, 256, 256, 128, True, None),       # MQA
    (1, 4, 4, 64, 192, 64, True, None),         # Tk > Tq (chunked prefill)
    (2, 2, 2, 160, 160, 80, False, None),       # bidirectional (encoder)
    (1, 4, 2, 256, 256, 64, True, 64),          # sliding window
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    b, hq, hkv, tq, tk, d, causal, window = case
    q = jnp.asarray(RNG.normal(size=(b, hq, tq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, tk, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, tk, d)), dtype)
    o1 = ops.flash_attention(q, k, v, causal=causal, window=window,
                             block_q=128, block_k=128)
    o2 = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    # (B, Hq, Hkv, S, D)
    (1, 1, 1, 128, 64),
    (2, 4, 1, 300, 64),       # MQA + uneven S
    (4, 8, 2, 1024, 128),
    (1, 2, 2, 96, 32),
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(case, dtype):
    b, hq, hkv, s, d = case
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), dtype)
    kv_len = jnp.asarray(RNG.integers(1, s + 1, b), jnp.int32)
    o1 = ops.decode_attention(q, k, v, kv_len, block_s=128)
    o2 = ref.decode_attention(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# paged_decode_attention (fused write-attend over a page pool)
# ---------------------------------------------------------------------------

PAGED_CASES = [
    # (B, Hq, Hkv, page_size, maxp, D, window)
    (1, 1, 1, 8, 2, 32, None),
    (2, 4, 1, 16, 4, 64, None),       # MQA
    (3, 4, 2, 10, 3, 16, None),       # unaligned page size (interpret)
    (2, 8, 2, 8, 4, 32, 11),          # sliding window mask
]


def _paged_setup(b, hq, hkv, ps, maxp, d, dtype, seed=0):
    r = np.random.default_rng(seed)
    pool = b * maxp + 2                       # spare pages stay untouched
    q = jnp.asarray(r.normal(size=(b, hq, d)), dtype)
    kp = jnp.asarray(r.normal(size=(pool, hkv, ps, d)), dtype)
    vp = jnp.asarray(r.normal(size=(pool, hkv, ps, d)), dtype)
    bt = jnp.asarray(r.permutation(pool)[:b * maxp].reshape(b, maxp)
                     .astype(np.int32))
    pos = jnp.asarray(r.integers(0, maxp * ps, b), jnp.int32)
    kn = jnp.asarray(r.normal(size=(b, hkv, d)), dtype)
    vn = jnp.asarray(r.normal(size=(b, hkv, d)), dtype)
    return q, kp, vp, bt, pos, kn, vn


@pytest.mark.parametrize("case", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sweep(case, dtype):
    b, hq, hkv, ps, maxp, d, window = case
    q, kp, vp, bt, pos, kn, vn = _paged_setup(b, hq, hkv, ps, maxp, d, dtype)
    o1, kp1, vp1 = ops.paged_decode_attention(q, kp, vp, bt, pos, kn, vn,
                                              window=window)
    o2, kp2, vp2 = ref.paged_decode_attention(q, kp, vp, bt, pos, kn, vn,
                                              window=window)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), **_tol(dtype))
    # The fused write must be bit-identical to the oracle's scatter — and
    # must touch only the written slots (pools otherwise unchanged).
    np.testing.assert_array_equal(np.asarray(kp1), np.asarray(kp2))
    np.testing.assert_array_equal(np.asarray(vp1), np.asarray(vp2))


def test_paged_matches_dense_decode_oracle():
    """Paged attend over scattered pages == dense decode over the
    contiguous cache the block table describes (same tokens, same math)."""
    b, hq, hkv, ps, maxp, d = 2, 4, 2, 8, 4, 32
    q, kp, vp, bt, pos, kn, vn = _paged_setup(b, hq, hkv, ps, maxp, d,
                                              jnp.float32)
    o, kp1, vp1 = ops.paged_decode_attention(q, kp, vp, bt, pos, kn, vn)
    # Gather each row's pages (post-write) into a dense [B, Hkv, S, D] cache.
    kd = np.moveaxis(np.asarray(kp1)[np.asarray(bt)], 2, 1).reshape(
        b, hkv, maxp * ps, d)
    vd = np.moveaxis(np.asarray(vp1)[np.asarray(bt)], 2, 1).reshape(
        b, hkv, maxp * ps, d)
    o_dense = ref.decode_attention(q, jnp.asarray(kd), jnp.asarray(vd),
                                   pos + 1)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_dense),
                               rtol=2e-5, atol=2e-5)


def test_paged_unallocated_row_drops_write_like_oracle():
    """A row whose block table is all -1 (unallocated) must not write —
    kernel and oracle agree the token is dropped, page 0 stays pristine."""
    b, hq, hkv, ps, maxp, d = 2, 2, 2, 8, 3, 16
    q, kp, vp, bt, pos, kn, vn = _paged_setup(b, hq, hkv, ps, maxp, d,
                                              jnp.float32)
    bt = jnp.asarray(np.asarray(bt)).at[1].set(-1)      # row 1 unallocated
    pos = jnp.asarray([5, 0], jnp.int32)
    o1, kp1, vp1 = ops.paged_decode_attention(q, kp, vp, bt, pos, kn, vn)
    o2, kp2, vp2 = ref.paged_decode_attention(q, kp, vp, bt, pos, kn, vn)
    np.testing.assert_array_equal(np.asarray(kp1), np.asarray(kp2))
    np.testing.assert_array_equal(np.asarray(vp1), np.asarray(vp2))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_paged_write_lands_at_pos_slot():
    b, hq, hkv, ps, maxp, d = 2, 2, 2, 8, 3, 16
    q, kp, vp, bt, pos, kn, vn = _paged_setup(b, hq, hkv, ps, maxp, d,
                                              jnp.float32)
    pos = jnp.asarray([0, 2 * ps + 3], jnp.int32)      # page starts & middles
    _, kp1, _ = ops.paged_decode_attention(q, kp, vp, bt, pos, kn, vn)
    kp1 = np.asarray(kp1)
    btn, posn = np.asarray(bt), np.asarray(pos)
    for i in range(b):
        pg, sl = btn[i, posn[i] // ps], posn[i] % ps
        np.testing.assert_array_equal(kp1[pg, :, sl], np.asarray(kn)[i])


def test_decode_attention_rejects_undivisible_block_s():
    """Direct kernel calls with block_s ∤ S must fail loudly, not drop the
    tail of the cache (ops.decode_attention pads before calling)."""
    from repro.kernels import decode_attention as dec
    q = jnp.zeros((2, 1, 128), jnp.float32)
    k = jnp.zeros((2, 300, 128), jnp.float32)
    with pytest.raises(ValueError, match="not a multiple"):
        dec.decode_attention(q, k, k, jnp.ones((2,), jnp.int32),
                             scale=1.0, num_q_heads=1, block_s=128,
                             interpret=True)


# ---------------------------------------------------------------------------
# linear_scan (RG-LRU recurrence)
# ---------------------------------------------------------------------------

SCAN_CASES = [
    (1, 8, 4), (2, 100, 16), (3, 256, 64), (1, 1000, 8),
]


@pytest.mark.parametrize("case", SCAN_CASES)
def test_linear_scan_sweep(case):
    b, t, d = case
    a = jnp.asarray(RNG.uniform(0.3, 0.999, size=(b, t, d)), jnp.float32)
    bb = jnp.asarray(RNG.normal(size=(b, t, d)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    y1, hT = ops.linear_scan(a, bb, h0, block_t=64)
    y2 = ref.linear_scan(a, bb, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(y2[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_rglru_reference_stability():
    """RG-LRU reference: decay in (0,1], bounded output, carries state."""
    b, t, d = 2, 64, 8
    x = jnp.asarray(RNG.normal(size=(b, t, d)), jnp.float32)
    ig = jnp.asarray(RNG.normal(size=(b, t, d)), jnp.float32)
    rg = jnp.asarray(RNG.normal(size=(b, t, d)), jnp.float32)
    lam = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
    h0 = jnp.zeros((b, d), jnp.float32)
    y, hT = ref.rglru(x, ig, rg, lam, h0)
    assert np.isfinite(np.asarray(y)).all()
    # Feeding the final state back reproduces a split computation.
    y1, h1 = ref.rglru(x[:, :32], ig[:, :32], rg[:, :32], lam, h0)
    y2, h2 = ref.rglru(x[:, 32:], ig[:, 32:], rg[:, 32:], lam, h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y), rtol=1e-5, atol=1e-5)
