"""Wire faults against the delta sync engine (core/delta.py).

The delta protocol's safety story is that packets are *join deltas*: apply
is idempotent and commutative, frontiers advance only over shipped cells,
and a sender re-extracts anything unacknowledged.  These tests put that
story on an adversarial wire: ``LogDelta`` / ``LWWDelta`` / ``PNDelta``
packets are dropped, duplicated, and reordered by a seeded channel, acks
travel over the same faulty wire, and the states must STILL converge
bit-for-bit to the ``merge.fold_join`` full-state oracle.

This is the packet-level analogue of tests/test_delta_properties.py (which
syncs losslessly) and the unit-level substrate under the replica simulator
(tests/test_replicated_pages.py, which faults the whole page-table
protocol).
"""
from __future__ import annotations

import heapq

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import counter, delta, gset, merge, todo

SEEDS = range(6)


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# Divergent-state builders (ops all happen before any sync)
# ---------------------------------------------------------------------------


def _glog_states(rng, n):
    base = gset.GLog.empty(n, 16, {"x": ((), jnp.int32)})
    replicas = [base for _ in range(n)]
    for _ in range(12):
        who = int(rng.integers(0, n))
        replicas[who] = replicas[who].append(
            jnp.int32(who), x=jnp.int32(rng.integers(1, 99)))
    return base, replicas


def _board_states(rng, n):
    base = todo.empty(8)
    replicas = [base for _ in range(n)]
    clocks = [1] * n
    for _ in range(12):
        who = int(rng.integers(0, n))
        key = int(rng.integers(0, 8))
        replicas[who] = todo.post(replicas[who], key,
                                  jnp.zeros((8,), bool),
                                  jnp.int32(clocks[who]), jnp.int32(who + 1))
        clocks[who] += 1
    return base, replicas


def _pn_states(rng, n):
    base = counter.PNCounter.zeros(n, 12)
    replicas = [base for _ in range(n)]
    for _ in range(16):
        who = int(rng.integers(0, n))
        key = int(rng.integers(0, 12))
        c = replicas[who]
        if rng.random() < 0.7 or int(c.inc[who, key] - c.dec[who, key]) == 0:
            c = c.add(who, key, int(rng.integers(1, 4)))
        else:
            c = c.sub(who, key)       # dec <= inc: only drop held refs
        replicas[who] = c
    return base, replicas


BUILDERS = {"glog": _glog_states, "board": _board_states, "pn": _pn_states}


# ---------------------------------------------------------------------------
# Faulty wire: acked-frontier senders over a drop/dup/reorder channel
# ---------------------------------------------------------------------------


def _faulty_sync(base, replicas, rng, *, drop, dup, delay_max, capacity=4,
                 rounds=40):
    """Anti-entropy over an adversarial wire.

    Each sender keeps, per peer, the last *acknowledged* frontier and
    re-extracts against it every round — exactly the AntiEntropyNode
    discipline.  Deltas AND acks ride the same faulty channel, so a lost
    ack forces a (harmless, idempotent) re-send and a duplicated delta is
    a no-op re-apply.  Returns the converged replicas.
    """
    n = len(replicas)
    genesis = delta.frontier(base)
    acked = {(s, d): genesis for s in range(n) for d in range(n) if s != d}
    pending: dict = {}                # (s, d, pkt_id) -> shipped frontier
    want = merge.fold_join(replicas)
    q: list = []                      # heap of (deliver_at, seq, payload)
    seq = 0
    pkt_id = 0
    for t in range(rounds):
        healed = t >= rounds // 2     # second half: reliable catch-up
        while q and q[0][0] <= t:
            _, _, msg = heapq.heappop(q)
            if msg[0] == "delta":
                _, s, d, pid, dlt = msg
                replicas[d] = delta.apply(replicas[d], dlt)
                ack = ("ack", s, d, pid)
                delay = 1 + (0 if healed else
                             int(rng.integers(0, delay_max + 1)))
                heapq.heappush(q, (t + delay, seq, ack))
                seq += 1
            else:
                _, s, d, pid = msg
                fr = pending.pop((s, d, pid), None)
                if fr is not None:
                    acked[(s, d)] = fr
        if all(_trees_equal(r, want) for r in replicas) and not q:
            break
        for s in range(n):
            for d in range(n):
                if s == d:
                    continue
                dlt, shipped = delta.extract(replicas[s], acked[(s, d)],
                                             capacity)
                if not healed and rng.random() < drop:
                    continue
                copies = 2 if (not healed and rng.random() < dup) else 1
                pending[(s, d, pkt_id)] = shipped
                for _ in range(copies):
                    delay = 1 + (0 if healed else
                                 int(rng.integers(0, delay_max + 1)))
                    heapq.heappush(
                        q, (t + delay, seq, ("delta", s, d, pkt_id, dlt)))
                    seq += 1
                pkt_id += 1
    return replicas, want


FAULTS = {
    "drop": dict(drop=0.5, dup=0.0, delay_max=0),
    "dup": dict(drop=0.0, dup=0.6, delay_max=0),
    "reorder": dict(drop=0.0, dup=0.0, delay_max=4),
    "all": dict(drop=0.3, dup=0.3, delay_max=3),
}


@pytest.mark.parametrize("fault", sorted(FAULTS))
@pytest.mark.parametrize("kind", sorted(BUILDERS))
@pytest.mark.parametrize("seed", SEEDS)
def test_delta_sync_survives_wire_faults(kind, fault, seed):
    rng = np.random.default_rng(1000 + seed)
    n = int(rng.integers(2, 5))
    base, replicas = BUILDERS[kind](rng, n)
    replicas, want = _faulty_sync(base, replicas,
                                  np.random.default_rng(2000 + seed),
                                  **FAULTS[fault])
    for i, r in enumerate(replicas):
        assert _trees_equal(r, want), (kind, fault, seed, i)


@pytest.mark.parametrize("kind", sorted(BUILDERS))
def test_duplicated_delta_is_idempotent(kind):
    rng = np.random.default_rng(42)
    base, replicas = BUILDERS[kind](rng, 3)
    fr = delta.frontier(base)
    tgt = base
    for r in replicas:
        d, _ = delta.extract(r, fr, 32)
        tgt = delta.apply(tgt, d)
        assert _trees_equal(tgt, delta.apply(tgt, d))   # dup -> no-op
    assert _trees_equal(tgt, merge.fold_join(replicas))


@pytest.mark.parametrize("kind", sorted(BUILDERS))
def test_reordered_deltas_commute(kind):
    """Applying a batch of deltas in any order lands the same bits."""
    rng = np.random.default_rng(43)
    base, replicas = BUILDERS[kind](rng, 4)
    fr = delta.frontier(base)
    deltas = [delta.extract(r, fr, 32)[0] for r in replicas]
    orders = [list(range(4)), [3, 1, 0, 2], [2, 3, 1, 0]]
    results = []
    for order in orders:
        tgt = base
        for i in order:
            tgt = delta.apply(tgt, deltas[i])
        results.append(tgt)
    for got in results[1:]:
        assert _trees_equal(got, results[0])
    assert _trees_equal(results[0], merge.fold_join(replicas))


def test_pn_counter_delta_capacity_overflow_converges():
    """More changed PN cells than packet capacity: unshipped cells stay
    behind the frontier and ship on later rounds (overflow liveness for
    the counter type added with the replicated page table)."""
    base = counter.PNCounter.zeros(2, 16)
    a = base
    for k in range(12):
        a = a.add(0, k, k + 1)
    fr = delta.frontier(base)
    peer = base
    for _ in range(6):
        d, fr = delta.extract(a, fr, 3)
        peer = delta.apply(peer, d)
        if _trees_equal(peer, a):
            break
    assert _trees_equal(peer, a)
    assert int(peer.value[5]) == 6
