"""Serving demo: batched requests through the engine (prefill + decode).

Four requests share one decode batch; per-row positions support continuous
batching.  Works with any registered arch (reduced config on CPU).

    PYTHONPATH=src python examples/serve_demo.py [arch]
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.models import lm
from repro.serving.engine import Engine

arch = sys.argv[1] if len(sys.argv) > 1 else "recurrentgemma-2b"
cfg = configs.reduced(configs.get(arch))
params = lm.init(jax.random.PRNGKey(0), cfg)

B, P, STEPS = 4, 8, 12
engine = Engine(cfg, params, batch=B, max_len=P + STEPS + cfg.num_prefix_tokens + 2)

rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(2, cfg.vocab_size, (B, P)), jnp.int32)
stubs = {}
if cfg.num_prefix_tokens:
    stubs["prefix_embeds"] = jnp.asarray(
        rng.normal(size=(B, cfg.num_prefix_tokens, cfg.d_model)), jnp.bfloat16)
if cfg.is_encdec:
    stubs["enc_frames"] = jnp.asarray(
        rng.normal(size=(B, cfg.encoder.seq_len, cfg.d_model)), jnp.bfloat16)

print(f"arch={arch} (reduced: L={cfg.num_layers} d={cfg.d_model}) "
      f"batch={B} prompt={P} steps={STEPS}")
out = engine.generate(prompts, STEPS, **stubs)
print("generated token grid [B, steps]:")
print(np.asarray(out))
print("per-row positions:", np.asarray(engine.pos))
