"""Elastic fault-tolerant training driven by the CRDT work queue.

Trains a ~100M-param class model (reduced here for CPU) for a few hundred
steps with two elastic workers; worker 1 is killed mid-run, its claimed data
shard goes stale, worker 2 reclaims it and finishes — loss continues from
the last checkpoint with bit-identical data.

    PYTHONPATH=src python examples/elastic_training.py [steps]
"""
import sys
import tempfile

import repro.configs as configs
from repro.data.pipeline import DataConfig
from repro.runtime.elastic import Worker, make_queue, make_shared_fold_sync
from repro.training.trainer import Trainer, TrainerConfig

steps = int(sys.argv[1]) if len(sys.argv) > 1 else 60

cfg = configs.reduced(configs.get("olmo-1b"), d_model=64, vocab=512)
data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=4,
                      shard_size_batches=4)
ckpt_dir = tempfile.mkdtemp(prefix="repro_elastic_")
tcfg = TrainerConfig(steps=steps, checkpoint_every=10,
                     checkpoint_dir=ckpt_dir, shard_timeout=50)

shared = {}
sync = make_shared_fold_sync(shared)
queue = make_queue(num_shards=max(steps // 4 + 2, 8), num_workers=2)

print(f"model={cfg.name}(reduced) steps={steps} ckpt={ckpt_dir}")

# Worker 1 trains, then 'crashes' mid-shard.
w1 = Worker(1, queue, sync, stale_timeout=50)
t1 = Trainer(cfg, data_cfg, tcfg)
out1 = t1.run(w1, now_fn=lambda: 0, fail_after_steps=steps // 3)
print(f"worker1 CRASHED at step {out1['step']} "
      f"(loss {out1['metrics'][-1]['loss']:.3f})")

# Worker 2 joins, restores the checkpoint, reclaims the stale shard.
w2 = Worker(2, shared["state"], sync, stale_timeout=50)
t2 = Trainer(cfg, data_cfg, tcfg)
restored = t2.maybe_restore()
print(f"worker2 restored={restored} at step {t2.step}")
reclaimed = w2.reclaim_stale(now=1000)
print(f"worker2 reclaimed {reclaimed} stale shard(s)")
out2 = t2.run(w2, now_fn=lambda: 1000)
losses = [m["loss"] for m in out2["metrics"]]
print(f"worker2 finished at step {out2['step']}; "
      f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
assert out2["step"] >= steps
print("OK: training survived worker failure with zero lost shards")
