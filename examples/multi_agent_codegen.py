"""End-to-end driver: parallel vs sequential multi-agent code generation.

Runs the paper's experiment on one task with a real (tiny) decoder serving
stack: batched decode, CRDT claims, observation-driven invalidation,
convergence check — then prints the seq/par comparison.

    PYTHONPATH=src python examples/multi_agent_codegen.py [task] [n_agents]
"""
import sys

from repro.agents.orchestrator import make_sim_llm, run_task
from repro.agents.tasks import TASKS

task_name = sys.argv[1] if len(sys.argv) > 1 else "dashboard"
n_agents = int(sys.argv[2]) if len(sys.argv) > 2 else 4

cfg, params = make_sim_llm()
task = TASKS[task_name]
print(f"task={task.name} coupling={task.coupling} todos={task.n_todos} "
      f"volume_inflation={task.par_inflation}x")

results = {}
for mode in ("sequential", "parallel"):
    r = run_task(cfg, params, task, mode=mode, n_agents=n_agents, seed=0)
    results[mode] = r
    print(f"\n{mode:>10s}: steps={r.steps}  wall={r.wall_s:.2f}s  "
          f"tokens={r.gen_tokens}  replayed={r.replay_tokens}")
    print(f"{'':>10s}  invalidations={r.invalidations}  "
          f"claim_collisions={r.claim_collisions}  "
          f"semantic_conflicts={r.semantic_conflicts}")
    print(f"{'':>10s}  converged={r.converged}  digest={r.digest}")

s, p = results["sequential"], results["parallel"]
print(f"\nraw response (decode steps): {s.steps} -> {p.steps} "
      f"({100 * (p.steps - s.steps) / s.steps:+.1f}%)")
print(f"normalized (steps / 1k tokens): {s.steps_per_1k_tokens:.0f} -> "
      f"{p.steps_per_1k_tokens:.0f} "
      f"({100 * (p.steps_per_1k_tokens - s.steps_per_1k_tokens) / s.steps_per_1k_tokens:+.1f}%)")
print("(paper's finding: raw time can invert on coupled tasks while "
      "normalized time still favors parallel)")

# Evaluator pass (paper §4.3): detect semantic conflicts the CRDT cannot
# see, auto-reconcile duplicates with rename patches (themselves CRDT edits).
from repro.agents import evaluator
from repro.agents.orchestrator import make_sim_llm as _m  # noqa: E402
from repro.core import doc as doc_mod
import jax.numpy as jnp

# Rebuild the merged doc from the parallel run's digest path: re-run briefly
# to get a document object for the demo.
r = run_task(cfg, params, task, mode="parallel", n_agents=n_agents, seed=0)
# (run_task returns metrics; for the demo, reconstruct a conflicted doc)
demo = doc_mod.empty(4, 32)
demo = doc_mod.append(demo, 0, jnp.asarray([5, 7, 0, 0]), 2)   # declares sym 5
demo = doc_mod.append(demo, 1, jnp.asarray([5, 9, 0, 0]), 2)   # duplicate!
fixed, report = evaluator.reconcile(demo)
print(f"\nevaluator: {len(report.conflicts)} conflict(s), "
      f"{report.fixed} auto-fixed, {len(report.flagged)} flagged")
print(f"scores: {evaluator.score(fixed)}")
