"""Quickstart: the CodeCRDT pattern in 60 lines.

Two simulated LLM agents implement a 4-TODO task concurrently, coordinating
only through CRDT state: optimistic claims with LWW arbitration, append-only
document slots, deterministic convergence.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import doc, merge, protocol, todo
from repro.core.clock import Lamport

K = 4

# 1. Outliner posts the TODO skeleton.
board = todo.empty(K)
lam_out = Lamport.create(client=99)
for k in range(K):
    lam_out = lam_out.tick()
    board = todo.post(board, k, jnp.zeros((K,), bool), lam_out.time,
                      lam_out.client)
print("posted:", board.status.tolist())

# 2. Two agents claim concurrently against the same snapshot; the CRDT
#    merge arbitrates deterministically (at-most-one winner per TODO).
clients = jnp.asarray([1, 2], jnp.int32)
clocks = jnp.asarray([10, 10], jnp.int32)        # adversarial tie!
board, picks, won = protocol.concurrent_claims(board, clients, clocks,
                                               jnp.int32(0))
print("picks:", picks.tolist(), "won:", won.tolist(),
      "assignees:", board.assignee.tolist())

# 3. Each winner writes code into its own *replica* of the document.
replica_1 = doc.empty(K, 32)
replica_2 = doc.empty(K, 32)
replica_1 = doc.append(replica_1, int(picks[0]),
                       jnp.asarray([104, 105, 0, 0]), 2)   # agent 1: "hi"
replica_2 = doc.append(replica_2, int(picks[1]),
                       jnp.asarray([33, 0, 0, 0]), 1)      # agent 2: "!"

# 4. Replicas converge through the join — in ANY order.
m12 = merge.join(replica_1, replica_2)
m21 = merge.join(replica_2, replica_1)
assert int(doc.digest(m12)) == int(doc.digest(m21))
flat, n = doc.render(m12)
print("converged document tokens:", flat[: int(n)].tolist())
print("digests equal:", int(doc.digest(m12)) == int(doc.digest(m21)))
